//! Loopback soak: the event-loop parameter-server service under a large
//! elastic fleet with injected disconnects and rejoins (DESIGN.md §11).
//!
//! What the soak certifies, beyond the in-module service tests:
//!
//! 1. **Scale** — a fleet of `LAG_SOAK_WORKERS` (default 64) real sockets
//!    against one single-threaded readiness loop.
//! 2. **Determinism under churn and timing faults** — with
//!    boundary-aligned scheduled drops/rejoins *and* seeded timing-only
//!    byte-level fault injection (short reads/writes, delays; seed via
//!    `LAG_SOAK_FAULT_SEED`, default 7), two independent executions
//!    produce byte-identical traces (records to the f64 bit, upload
//!    events, final iterate).
//! 3. **Bounded failure** — a fleet that never shows up is a prompt,
//!    worker-identifying error, not a hang; the whole soak respects a
//!    wall-clock budget.
//! 4. **Unplanned chaos** — worker threads killed at arbitrary (timing-
//!    dependent) points never wedge the leader; survivors finish the run.
//!
//! CI runs this with `cargo test --release --test soak`; locally a smaller
//! fleet can be chosen via the env var, e.g. `LAG_SOAK_WORKERS=16`.

mod common;

use common::{drive, env_fleet, record_sig, sopts, theta_bits, WALL_BUDGET};
use lag::coordinator::{
    run_service, serve_worker, Algorithm, FaultConfig, FaultPlan, RunOptions, ServiceOptions,
    WorkerConfig, WorkerExit,
};
use lag::data::synthetic;
use std::net::TcpListener;
use std::time::{Duration, Instant};

/// Fleet size: `LAG_SOAK_WORKERS`, default 64 — the acceptance bar.
/// Clamped to ≥ 8 so the churn fault plan always has shards to drop.
fn fleet_size() -> usize {
    env_fleet("LAG_SOAK_WORKERS", 64, 8)
}

/// The headline soak: a ≥ 64-worker fleet with a dozen scheduled
/// disconnect/rejoin pairs spread across the run. Two executions must be
/// byte-identical, every injected fault must be visible in the stats, and
/// both runs must land inside the wall budget.
#[test]
fn churn_soak_is_byte_identical_across_runs() {
    let m = fleet_size();
    let p = synthetic::linreg_increasing_l(m, 8, 6, 1007);
    let opts = RunOptions { max_iters: 28, record_every: 1, ..Default::default() };

    // Spread drops across shards and rounds: every 5th shard drops after
    // round 4 (rejoining at 9) or after round 13 (rejoining at 18).
    let mut faults = FaultPlan::default();
    for (i, s) in (0..m).step_by(5).enumerate() {
        let (drop_k, admit_k) = if i % 2 == 0 { (4, 9) } else { (13, 18) };
        faults.drop_after.push((drop_k, s));
        faults.admit_at.push((admit_k, s));
    }
    let injected = faults.drop_after.len() as u64;
    assert!(injected >= 2, "fault plan too small to exercise churn");
    // Timing-only byte-level injection on top of the churn: short
    // reads/writes and delays chop the leader's socket I/O but are
    // trace-neutral by contract, so the byte-compare below still holds.
    let fault_seed = std::env::var("LAG_SOAK_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7u64);
    faults.io = FaultConfig::timing_only(fault_seed);

    let t0 = Instant::now();
    let (ta, sa) = drive(&p, Algorithm::LagWk, &opts, &sopts(), &faults);
    let (tb, sb) = drive(&p, Algorithm::LagWk, &opts, &sopts(), &faults);
    let elapsed = t0.elapsed();
    assert!(elapsed < WALL_BUDGET, "soak blew the wall budget: {elapsed:?}");

    // Byte-identical traces: every record (objective to the f64 bit,
    // communication counters), every upload event, the final iterate.
    assert_eq!(record_sig(&ta.records), record_sig(&tb.records));
    assert_eq!(ta.upload_events, tb.upload_events);
    assert_eq!(theta_bits(&sa.final_theta), theta_bits(&sb.final_theta));

    // Every injected fault really happened, in both runs.
    assert_eq!(sa.evictions, injected);
    assert_eq!(sb.evictions, injected);
    assert_eq!(sa.joins, m as u64 + injected);
    assert_eq!(sb.joins, m as u64 + injected);

    // Dropped shards were dark during their windows and forced a
    // first-contact upload at the re-admission round.
    for (&(drop_k, s), &(admit_k, _)) in faults.drop_after.iter().zip(&faults.admit_at) {
        assert!(
            ta.upload_events[s].iter().all(|&k| k <= drop_k || k >= admit_k),
            "shard {s} uploaded while dropped"
        );
        assert!(
            ta.upload_events[s].contains(&admit_k),
            "shard {s} missing its forced rejoin upload at k={admit_k}"
        );
    }

    // And the run still optimizes: the recorded objective error falls.
    let first = ta.records.first().unwrap().obj_err;
    let last = ta.records.last().unwrap().obj_err;
    assert!(last < first, "objective did not decrease: {first} -> {last}");
}

/// A fleet that never connects is a deadline error naming the missing
/// shards — within the configured timeout, not a hang (the seed runtime's
/// failure mode).
#[test]
fn absent_fleet_fails_fast_with_named_shards() {
    let m = fleet_size().min(8); // error path; no need for the full fleet
    let p = synthetic::linreg_increasing_l(m, 8, 6, 1008);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let so = ServiceOptions {
        join_timeout: Duration::from_millis(250),
        tick: Duration::from_millis(1),
        ..sopts()
    };
    let opts = RunOptions { max_iters: 5, ..Default::default() };

    let t0 = Instant::now();
    let err = run_service(listener, &p, Algorithm::LagWk, &opts, &so, &FaultPlan::default())
        .unwrap_err()
        .to_string();
    let elapsed = t0.elapsed();

    assert!(elapsed < Duration::from_secs(10), "deadline took {elapsed:?}");
    assert!(err.contains(&format!("0/{m}")), "error should count members: {err}");
    assert!(err.contains("unowned shards"), "error should name shards: {err}");
}

/// Unplanned chaos: a third of the fleet joins, then dies at a
/// timing-dependent moment — connection dropped cold, mid-membership,
/// never replying to a broadcast. The byte-compare does not apply (arrival
/// timing decides the eviction rounds) but the leader must finish every
/// round with the survivors, inside the budget, and still optimize.
#[test]
fn worker_kill_chaos_never_wedges_the_leader() {
    use lag::coordinator::WireMsg;
    use std::io::Write;

    let m = fleet_size();
    let p = synthetic::linreg_increasing_l(m, 8, 6, 1009);
    let opts = RunOptions { max_iters: 25, record_every: 1, ..Default::default() };
    let deserters = (0..m).filter(|s| s % 3 == 0 && *s > 0).count() as u64;
    assert!(deserters >= 2);
    let so = ServiceOptions {
        // Don't let round 1 hinge on the deserters: if one dies before
        // admission, the run must still start (with the survivors).
        min_workers: m - deserters as usize,
        round_timeout: Duration::from_secs(3),
        heartbeat_timeout: Duration::from_secs(3),
        ..sopts()
    };

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let t0 = Instant::now();
    let p = &p;
    let (trace, stats) = std::thread::scope(|scope| {
        let leader = scope.spawn(|| {
            run_service(listener, p, Algorithm::LagWk, &opts, &so, &FaultPlan::default())
                .unwrap()
        });
        for s in 0..m {
            let addr = addr.clone();
            if s % 3 == 0 && s > 0 {
                // Deserter: join the fleet, hold the shard long enough to
                // be broadcast to, then vanish without a goodbye.
                scope.spawn(move || {
                    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
                    stream.write_all(&WireMsg::Hello { worker: s as u32 }.encode()).unwrap();
                    std::thread::sleep(Duration::from_millis(600));
                    // dropping the stream here is the kill
                });
            } else {
                scope.spawn(move || {
                    let cfg = WorkerConfig {
                        preferred: Some(s),
                        heartbeat_interval: Duration::from_millis(20),
                        leader_timeout: Duration::from_secs(90),
                        ..Default::default()
                    };
                    loop {
                        match serve_worker(&addr, p, &cfg) {
                            Ok(o) if o.exit == WorkerExit::Shutdown => break,
                            Ok(_) => std::thread::sleep(Duration::from_millis(2)),
                            Err(_) => break,
                        }
                    }
                });
            }
        }
        leader.join().unwrap()
    });
    let elapsed = t0.elapsed();
    assert!(elapsed < WALL_BUDGET, "chaos soak blew the wall budget: {elapsed:?}");

    // All rounds ran; deserters were detected and evicted (only admitted
    // ones count — a deserter dying pre-admission is just a dropped
    // connection); no survivor was ever evicted; the objective still fell.
    assert_eq!(trace.records.last().unwrap().k, opts.max_iters);
    assert!(stats.evictions >= 1, "no deserter was ever evicted");
    assert!(
        stats.evictions <= deserters,
        "{} evictions but only {deserters} deserters — a survivor was evicted",
        stats.evictions
    );
    assert!(
        stats.joins >= m as u64 - deserters,
        "the surviving fleet never fully assembled"
    );
    let first = trace.records.first().unwrap().obj_err;
    let last = trace.records.last().unwrap().obj_err;
    assert!(last < first, "objective did not decrease under chaos");
}
