//! Integration tests over the full three-layer stack: the Rust coordinator
//! driving gradients through the AOT'd JAX+Pallas artifacts via PJRT.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise) and
//! the `pjrt` cargo feature (the whole suite is compiled out without it —
//! the stub engine cannot execute artifacts).

#![cfg(feature = "pjrt")]

use lag::coordinator::{run, Algorithm, RunOptions};
use lag::data::synthetic;
use lag::grad::{GradEngine, NativeEngine};
use lag::runtime::{Manifest, PjrtEngine};

fn artifacts_ready() -> bool {
    Manifest::load("artifacts").is_ok()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn pjrt_matches_native_linreg_gradients() {
    require_artifacts!();
    let p = synthetic::linreg_increasing_l(9, 50, 50, 99);
    let pjrt = PjrtEngine::new(&p, "artifacts").unwrap();
    let native = NativeEngine::new(&p);
    let mut rng = lag::util::Rng::new(5);
    for trial in 0..5 {
        let theta = rng.normal_vec(50);
        for m in [0, 4, 8] {
            let (gp, lp) = pjrt.grad(m, &theta);
            let (gn, ln) = native.grad(m, &theta);
            let scale = ln.abs().max(1.0);
            assert!(
                (lp - ln).abs() < 1e-9 * scale,
                "trial {trial} worker {m}: loss {lp} vs {ln}"
            );
            for (a, b) in gp.iter().zip(&gn) {
                assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "grad mismatch {a} vs {b}");
            }
        }
    }
}

#[test]
fn pjrt_matches_native_logreg_gradients() {
    require_artifacts!();
    let p = synthetic::logreg_uniform_l(9, 50, 50, 77);
    let pjrt = PjrtEngine::new(&p, "artifacts").unwrap();
    let native = NativeEngine::new(&p);
    let mut rng = lag::util::Rng::new(6);
    for _ in 0..5 {
        let theta = rng.normal_vec(50);
        for m in 0..9 {
            let (gp, lp) = pjrt.grad(m, &theta);
            let (gn, ln) = native.grad(m, &theta);
            assert!((lp - ln).abs() < 1e-9 * ln.abs().max(1.0));
            for (a, b) in gp.iter().zip(&gn) {
                assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()));
            }
        }
    }
}

#[test]
fn pjrt_full_lag_wk_run_matches_native_trace() {
    require_artifacts!();
    let p = synthetic::linreg_increasing_l(9, 50, 50, 1234);
    let opts = RunOptions { max_iters: 150, target_err: Some(1e-8), ..Default::default() };
    let en = NativeEngine::new(&p);
    let tn = run(&p, Algorithm::LagWk, &opts, &en);
    let ep = PjrtEngine::new(&p, "artifacts").unwrap();
    let tp = run(&p, Algorithm::LagWk, &opts, &ep);
    // the engines agree to ~1e-12 per gradient; upload patterns may only
    // differ at exact trigger ties, which don't occur generically
    assert_eq!(tn.total_uploads(), tp.total_uploads());
    assert_eq!(tn.upload_events, tp.upload_events);
    assert_eq!(tn.converged_iter, tp.converged_iter);
}

#[test]
fn pjrt_lag_ps_converges_on_real_shapes() {
    require_artifacts!();
    // exercise the padded 176x8 artifact through the fig5 problem builder
    let p = lag::experiments::fig5::problem(3).unwrap();
    assert_eq!(p.workers[0].n_padded(), 176);
    let opts = RunOptions { max_iters: 4000, target_err: Some(1e-6), ..Default::default() };
    let e = PjrtEngine::new(&p, "artifacts").unwrap();
    let t = run(&p, Algorithm::LagPs, &opts, &e);
    assert!(
        t.final_err() < 1e-4,
        "LAG-PS should make clear progress on fig5 shapes, err={}",
        t.final_err()
    );
}

#[test]
fn pjrt_engine_reports_artifact_and_calls() {
    require_artifacts!();
    let p = synthetic::linreg_increasing_l(3, 50, 50, 4);
    let e = PjrtEngine::new(&p, "artifacts").unwrap();
    assert_eq!(e.artifact, "linreg_grad_50x50");
    assert_eq!(e.name(), "pjrt");
    let theta = vec![0.0; 50];
    e.grad(0, &theta);
    e.grad(1, &theta);
    assert_eq!(e.calls(), 2);
}

#[test]
fn pjrt_rejects_unregistered_shape() {
    require_artifacts!();
    // n=50,d=13 has no artifact — the engine must fail with a clear error
    let p = synthetic::linreg_increasing_l(3, 50, 13, 4);
    let err = match PjrtEngine::new(&p, "artifacts") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected shape-mismatch error"),
    };
    assert!(err.contains("no linreg artifact"), "{err}");
}

#[test]
fn transformer_tiny_step_runs_and_improves() {
    require_artifacts!();
    use lag::transformer::{lag_train, synth_corpus, LmTrainOptions, TransformerTrainer};
    let tr = TransformerTrainer::new("artifacts", "transformer_step_tiny").unwrap();
    let corpora: Vec<Vec<i32>> = (0..2).map(|m| synth_corpus(&tr.meta, m, 3)).collect();
    let opts = LmTrainOptions {
        algo: Algorithm::LagWk,
        steps: 12,
        alpha: 0.25, // on the 2-worker sum objective
        d_history: 10,
        xi: 0.1,
    };
    let recs = lag_train(&tr, &corpora, &opts).unwrap();
    assert_eq!(recs.len(), 12);
    let first = recs[0].mean_loss;
    let last = recs.last().unwrap().mean_loss;
    assert!(last < first, "LM loss should drop: {first} -> {last}");
    // LAG must not exceed the GD upload budget
    assert!(recs.last().unwrap().cum_uploads <= 12 * 2);
}
