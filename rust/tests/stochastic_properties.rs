//! Property suite for the stochastic (minibatch) subsystem.
//!
//! The contracts under test (DESIGN.md §10):
//!
//! * **Sampling is pure** in `(seed, worker, iter)` — the same batch comes
//!   back whatever thread computes it, concurrently or not, so stochastic
//!   traces can never depend on pool size or scheduler width.
//! * **Batches are well-formed** — ascending, duplicate-free, inside the
//!   shard's real rows, exactly the specified size.
//! * **Dense and CSR storage agree bitwise** on every minibatch gradient,
//!   exactly like the full-batch kernels — format selection can never
//!   change a stochastic trace.
//! * **Full-batch specs change nothing** — `BatchSpec::Full` runs are
//!   byte-identical to the pre-stochastic driver.

use lag::coordinator::{run, Algorithm, RunOptions};
use lag::data::{synthetic, ShardStorage, Task};
use lag::grad::{sample_rows_into, worker_grad_batch, BatchSpec, NativeEngine};
use lag::linalg::CsrMatrix;
use lag::util::Rng;

#[test]
fn sampling_is_identical_across_threads() {
    // 8 threads race to sample the same (seed, worker, iter) grid; every
    // result must equal the sequential reference
    let spec = BatchSpec::Fixed(7);
    let n = 41;
    let reference: Vec<Vec<u32>> = (0..60)
        .map(|i| {
            let (worker, iter) = (i % 6, (i / 6) as u64);
            let mut rows = Vec::new();
            sample_rows_into(spec, n, 5, worker, iter, &mut rows);
            rows
        })
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..8 {
            let reference = &reference;
            scope.spawn(move || {
                let mut rows = Vec::new();
                for i in 0..60 {
                    let (worker, iter) = (i % 6, (i / 6) as u64);
                    sample_rows_into(spec, n, 5, worker, iter, &mut rows);
                    assert_eq!(rows, reference[i], "worker {worker} iter {iter}");
                }
            });
        }
    });
}

#[test]
fn batches_are_sorted_unique_and_sized() {
    let mut checked = 0usize;
    for n in [1, 2, 7, 64, 333] {
        for spec in [
            BatchSpec::Full,
            BatchSpec::Fixed(1),
            BatchSpec::Fixed(5),
            BatchSpec::Fixed(1000),
            BatchSpec::Fraction(0.1),
            BatchSpec::Fraction(0.5),
            BatchSpec::Fraction(1.0),
        ] {
            let expect = spec.size_for(n);
            let mut rows = Vec::new();
            for worker in 0..4 {
                for iter in 0..25 {
                    sample_rows_into(spec, n, 11, worker, iter, &mut rows);
                    assert_eq!(rows.len(), expect, "n={n} {spec:?}");
                    assert!(rows.windows(2).all(|w| w[0] < w[1]), "n={n} {spec:?}: {rows:?}");
                    assert!(rows.iter().all(|&r| (r as usize) < n));
                    checked += 1;
                }
            }
        }
    }
    assert!(checked > 0);
}

/// Workers' batches are independent streams: two workers at the same
/// iteration (and one worker at two iterations) almost never draw the
/// same subset.
#[test]
fn worker_streams_are_distinct() {
    let spec = BatchSpec::Fixed(10);
    let n = 200;
    let mut a = Vec::new();
    let mut b = Vec::new();
    let mut collisions = 0;
    for iter in 0..200 {
        sample_rows_into(spec, n, 21, 0, iter, &mut a);
        sample_rows_into(spec, n, 21, 1, iter, &mut b);
        if a == b {
            collisions += 1;
        }
    }
    assert_eq!(collisions, 0, "distinct workers drew identical batches");
}

/// The dense and CSR minibatch kernels must agree bitwise on any batch —
/// same contract as the full-batch kernels (DESIGN.md §8), extended to
/// row subsets.
#[test]
fn dense_and_csr_batch_gradients_agree_bitwise() {
    use lag::data::partition::pad_shard_storage;
    let mut rng = Rng::new(33);
    for (task, pm) in [(Task::LinReg, false), (Task::LogReg { lam: 1e-3 }, true)] {
        for density in [0.05, 0.2, 0.7] {
            let n = 31;
            let d = 18;
            let mut x = lag::linalg::Matrix::zeros(n, d);
            for i in 0..n {
                for j in 0..d {
                    if rng.uniform() < density {
                        x.set(i, j, rng.normal());
                    }
                }
            }
            let y: Vec<f64> = if pm {
                (0..n).map(|_| rng.sign()).collect()
            } else {
                rng.normal_vec(n)
            };
            let dense = pad_shard_storage(ShardStorage::Dense(x.clone()), y.clone(), n + 4);
            let csr = pad_shard_storage(ShardStorage::Csr(CsrMatrix::from_dense(&x)), y, n + 4);
            let theta = rng.normal_vec(d);
            for (worker, iter) in [(0, 1), (2, 9), (5, 40)] {
                let mut rows = Vec::new();
                sample_rows_into(BatchSpec::Fixed(9), n, 3, worker, iter, &mut rows);
                let scale = n as f64 / rows.len() as f64;
                let (gd, ld) = worker_grad_batch(task, &dense, &theta, &rows, scale);
                let (gc, lc) = worker_grad_batch(task, &csr, &theta, &rows, scale);
                assert_eq!(gd, gc, "{task:?} density {density} batch {rows:?}");
                assert_eq!(ld.to_bits(), lc.to_bits(), "{task:?} density {density}");
            }
        }
    }
}

/// Stochastic runs over CSR problems are bit-identical to the same
/// problem densified — the storage format is invisible to LASG too.
#[test]
fn stochastic_traces_are_storage_format_invariant() {
    let p_csr = synthetic::sparse_logreg(5, 24, 14, 0.12, 61);
    assert!(p_csr.workers.iter().all(|s| s.storage.is_csr()));
    let mut p_dense = p_csr.clone();
    for s in &mut p_dense.workers {
        s.storage = ShardStorage::Dense(s.storage.to_dense());
    }
    let opts = RunOptions {
        max_iters: 120,
        batch: BatchSpec::Fraction(0.3),
        record_thetas: true,
        ..Default::default()
    };
    for algo in Algorithm::STOCHASTIC {
        let a = run(&p_csr, algo, &opts, &NativeEngine::new(&p_csr));
        let b = run(&p_dense, algo, &opts, &NativeEngine::new(&p_dense));
        assert_eq!(a.upload_events, b.upload_events, "{algo:?}");
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.obj_err.to_bits(), y.obj_err.to_bits(), "{algo:?} k={}", x.k);
        }
        for (x, y) in a.thetas.iter().zip(&b.thetas) {
            for (va, vb) in x.iter().zip(y) {
                assert_eq!(va.to_bits(), vb.to_bits(), "{algo:?}");
            }
        }
    }
}

/// `RunOptions::threads` must not affect stochastic traces (the LASG
/// family runs the sequential loop for every requested width).
#[test]
fn stochastic_traces_ignore_thread_count() {
    let p = synthetic::linreg_increasing_l(6, 25, 10, 62);
    for algo in Algorithm::STOCHASTIC {
        let mk = |threads| {
            let opts = RunOptions {
                max_iters: 100,
                threads,
                batch: BatchSpec::Fixed(8),
                ..Default::default()
            };
            run(&p, algo, &opts, &NativeEngine::new(&p))
        };
        let seq = mk(1);
        for threads in [0, 2, 8] {
            let par = mk(threads);
            assert_eq!(seq.upload_events, par.upload_events, "{algo:?} threads={threads}");
            for (a, b) in seq.records.iter().zip(&par.records) {
                assert_eq!(a.obj_err.to_bits(), b.obj_err.to_bits(), "{algo:?} k={}", a.k);
            }
        }
    }
}
