//! Property suite for the sparse storage tier: dense and CSR kernels must
//! agree on random shards across the whole density range — **bitwise**,
//! because every CSR kernel preserves the dense summation order (`spdot`,
//! `scatter_axpy`, the fused gradient/loss kernels, matvecs, and the
//! setup-time Gram product, whose additions each target their own
//! accumulator cell). This is the license behind automatic format
//! selection (DESIGN.md §8).

use lag::data::partition::{pad_shard, pad_shard_storage};
use lag::data::{synthetic, worker_loss, ShardStorage, Task, WorkerShard};
use lag::grad::worker_grad;
use lag::linalg::{sparse, CsrMatrix, MatOps, Matrix};
use lag::util::Rng;

const DENSITIES: &[f64] = &[0.0, 0.02, 0.1, 0.3, 0.7, 1.0];
const TASKS: &[Task] = &[Task::LinReg, Task::LogReg { lam: 1e-3 }];

/// Dense view of the shared sparse generator — the property suite draws
/// from the same distribution the workloads and benches use.
fn random_dense(n: usize, d: usize, density: f64, rng: &mut Rng) -> Matrix {
    synthetic::gen_sparse_x(rng, n, d, density).to_dense()
}

fn shard_pair(
    n: usize,
    d: usize,
    density: f64,
    pad_to: usize,
    pm_labels: bool,
    rng: &mut Rng,
) -> (WorkerShard, WorkerShard) {
    let x = random_dense(n, d, density, rng);
    let y: Vec<f64> = if pm_labels {
        (0..n).map(|_| rng.sign()).collect()
    } else {
        rng.normal_vec(n)
    };
    let dense = pad_shard_storage(ShardStorage::Dense(x.clone()), y.clone(), pad_to);
    let csr = pad_shard_storage(ShardStorage::Csr(CsrMatrix::from_dense(&x)), y, pad_to);
    (dense, csr)
}

#[test]
fn gradients_and_losses_bitwise_agree_across_densities() {
    let mut rng = Rng::new(101);
    for &task in TASKS {
        for &density in DENSITIES {
            for (n, d, pad) in [(23, 9, 23), (17, 32, 24), (5, 101, 8)] {
                let pm = matches!(task, Task::LogReg { .. });
                let (dense, csr) = shard_pair(n, d, density, pad.max(n), pm, &mut rng);
                let theta = rng.normal_vec(d);
                let (gd, ld) = worker_grad(task, &dense, &theta);
                let (gc, lc) = worker_grad(task, &csr, &theta);
                assert_eq!(gd, gc, "{task:?} n={n} d={d} density={density}: gradient");
                assert_eq!(
                    ld.to_bits(),
                    lc.to_bits(),
                    "{task:?} n={n} d={d} density={density}: grad-pass loss"
                );
                let wd = worker_loss(task, &dense, &theta);
                let wc = worker_loss(task, &csr, &theta);
                assert_eq!(
                    wd.to_bits(),
                    wc.to_bits(),
                    "{task:?} n={n} d={d} density={density}: worker_loss"
                );
            }
        }
    }
}

#[test]
fn spdot_and_matvecs_bitwise_agree() {
    let mut rng = Rng::new(103);
    for &density in DENSITIES {
        // d values straddling the 4-wide block boundary
        for d in [1usize, 3, 4, 5, 11, 64, 65] {
            let n = 13;
            let x = random_dense(n, d, density, &mut rng);
            let a = CsrMatrix::from_dense(&x);
            let v = rng.normal_vec(d);
            let r = rng.normal_vec(n);
            for i in 0..n {
                let (cs, vs) = a.row(i);
                assert_eq!(
                    sparse::spdot(cs, vs, &v).to_bits(),
                    lag::linalg::dot(x.row(i), &v).to_bits(),
                    "d={d} density={density} row={i}"
                );
            }
            assert_eq!(a.matvec(&v), x.matvec(&v), "matvec d={d} density={density}");
            assert_eq!(a.t_matvec(&r), x.t_matvec(&r), "t_matvec d={d} density={density}");
        }
    }
}

#[test]
fn scatter_axpy_bitwise_matches_dense_axpy() {
    let mut rng = Rng::new(104);
    for &density in DENSITIES {
        let d = 37;
        let x = random_dense(1, d, density, &mut rng);
        let a = CsrMatrix::from_dense(&x);
        let alpha = rng.normal();
        let mut dense_out = rng.normal_vec(d);
        let mut csr_out = dense_out.clone();
        lag::linalg::axpy(alpha, x.row(0), &mut dense_out);
        let (cs, vs) = a.row(0);
        sparse::scatter_axpy(alpha, cs, vs, &mut csr_out);
        for (j, (u, w)) in dense_out.iter().zip(&csr_out).enumerate() {
            assert_eq!(u.to_bits(), w.to_bits(), "density={density} j={j}");
        }
    }
}

#[test]
fn gram_bitwise_agrees() {
    let mut rng = Rng::new(105);
    for &density in &[0.05, 0.3, 1.0] {
        let x = random_dense(40, 12, density, &mut rng);
        let a = CsrMatrix::from_dense(&x);
        assert_eq!(x.gram(), a.gram(), "density={density}");
    }
}

#[test]
fn problem_build_is_format_neutral() {
    // the same data built from Dense shards and from CSR shards must agree
    // on every derived quantity — L_m, L, θ*, L(θ*) — to the bit, for both
    // tasks (the build path only uses order-preserving kernels)
    use lag::data::Problem;
    let mut rng = Rng::new(110);
    for &task in TASKS {
        let mut dense_shards = Vec::new();
        let mut csr_shards = Vec::new();
        for _ in 0..3 {
            let x = random_dense(25, 8, 0.12, &mut rng);
            let y: Vec<f64> = if matches!(task, Task::LogReg { .. }) {
                (0..25).map(|_| rng.sign()).collect()
            } else {
                rng.normal_vec(25)
            };
            dense_shards.push((ShardStorage::Dense(x.clone()), y.clone()));
            csr_shards.push((ShardStorage::Csr(CsrMatrix::from_dense(&x)), y));
        }
        let pd = Problem::build_storage("fmt", task, dense_shards, None).unwrap();
        let pc = Problem::build_storage("fmt", task, csr_shards, None).unwrap();
        assert_eq!(pd.l_m, pc.l_m, "{task:?}: L_m");
        assert_eq!(pd.l_total.to_bits(), pc.l_total.to_bits(), "{task:?}: L");
        assert_eq!(pd.theta_star, pc.theta_star, "{task:?}: theta_star");
        assert_eq!(pd.loss_star.to_bits(), pc.loss_star.to_bits(), "{task:?}: loss_star");
    }
}

#[test]
fn power_iteration_is_format_neutral() {
    let mut rng = Rng::new(106);
    let x = random_dense(30, 10, 0.15, &mut rng);
    let a = ShardStorage::Csr(CsrMatrix::from_dense(&x));
    let ld = lag::linalg::power_iteration_gram(&x, 1e-12, 50_000);
    let lc = lag::linalg::power_iteration_gram(&a, 1e-12, 50_000);
    assert_eq!(
        ld.to_bits(),
        lc.to_bits(),
        "matvec-only power iteration must not see the storage format"
    );
}

#[test]
fn auto_selection_thresholds_and_padding() {
    let mut rng = Rng::new(107);
    // sparse data → CSR, fully dense data → dense
    let xs = random_dense(20, 10, 0.05, &mut rng);
    let s = pad_shard(xs, rng.normal_vec(20), 32);
    assert!(s.storage.is_csr());
    assert_eq!(s.n_padded(), 32);
    assert!(s.density() <= 0.25);
    let xd = random_dense(20, 10, 1.0, &mut rng);
    let s = pad_shard(xd, rng.normal_vec(20), 32);
    assert!(!s.storage.is_csr());
    // padding must not affect either format's gradient (pad rows are free
    // in CSR and zero-weight in dense)
    for &task in TASKS {
        let mut r2 = Rng::new(108);
        let (tight_d, tight_c) = shard_pair(15, 8, 0.1, 15, false, &mut r2);
        let mut r2 = Rng::new(108);
        let (padded_d, padded_c) = shard_pair(15, 8, 0.1, 40, false, &mut r2);
        let theta = vec![0.3; 8];
        let (g1, l1) = worker_grad(task, &tight_d, &theta);
        let (g2, l2) = worker_grad(task, &padded_d, &theta);
        let (g3, l3) = worker_grad(task, &tight_c, &theta);
        let (g4, l4) = worker_grad(task, &padded_c, &theta);
        assert_eq!(g1, g2);
        assert_eq!(g1, g3);
        assert_eq!(g1, g4);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(l1.to_bits(), l3.to_bits());
        assert_eq!(l1.to_bits(), l4.to_bits());
    }
}

#[test]
fn storage_views_are_consistent() {
    let mut rng = Rng::new(109);
    let x = random_dense(12, 7, 0.2, &mut rng);
    let c = CsrMatrix::from_dense(&x);
    let storage = ShardStorage::Csr(c.clone());
    assert_eq!(storage.rows(), 12);
    assert_eq!(storage.cols(), 7);
    assert_eq!(storage.nnz(), c.nnz());
    assert_eq!(storage.work_per_pass(), c.nnz());
    assert_eq!(storage.to_dense(), x);
    let dense = ShardStorage::Dense(x.clone());
    assert_eq!(dense.nnz(), c.nnz());
    assert_eq!(dense.work_per_pass(), 12 * 7);
    let v = rng.normal_vec(7);
    assert_eq!(storage.matvec(&v), dense.matvec(&v));
}
