//! Bit-determinism suite: the parallel gradient pool must reproduce the
//! sequential driver *exactly* — same `records` (to the bit), same
//! `upload_events`, same iterate sequence — for every algorithm, thread
//! count, and task (DESIGN.md §6).
//!
//! This is what licenses the driver to pick a thread count freely (auto
//! mode): the trace is a pure function of (problem, algorithm, options,
//! seed), never of the host's core count or scheduler.

use lag::coordinator::{run, Algorithm, RunOptions, RunTrace};
use lag::data::{synthetic, Problem};
use lag::experiments::{report, table5::Table5Result, ExpContext, ProblemKey, RunSpec};
use lag::grad::NativeEngine;
use std::collections::BTreeMap;
use std::sync::Arc;

fn assert_bit_identical(a: &RunTrace, b: &RunTrace, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.k, y.k, "{label}: record k");
        assert_eq!(
            x.obj_err.to_bits(),
            y.obj_err.to_bits(),
            "{label}: obj_err at k={} ({} vs {})",
            x.k,
            x.obj_err,
            y.obj_err
        );
        assert_eq!(x.cum_uploads, y.cum_uploads, "{label}: uploads at k={}", x.k);
        assert_eq!(x.cum_downloads, y.cum_downloads, "{label}: downloads at k={}", x.k);
        assert_eq!(x.cum_grad_evals, y.cum_grad_evals, "{label}: grad_evals at k={}", x.k);
    }
    assert_eq!(a.upload_events, b.upload_events, "{label}: upload events");
    assert_eq!(a.converged_iter, b.converged_iter, "{label}: converged_iter");
    assert_eq!(a.uploads_at_target, b.uploads_at_target, "{label}: uploads_at_target");
    assert_eq!(a.thetas.len(), b.thetas.len(), "{label}: theta count");
    for (k, (ta, tb)) in a.thetas.iter().zip(&b.thetas).enumerate() {
        for (j, (va, vb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{label}: theta[{k}][{j}] {va} vs {vb}"
            );
        }
    }
}

fn opts(threads: usize) -> RunOptions {
    RunOptions {
        max_iters: 120,
        record_thetas: true,
        threads,
        ..Default::default()
    }
}

fn problems() -> Vec<Problem> {
    vec![
        synthetic::linreg_increasing_l(9, 25, 12, 41),
        synthetic::logreg_uniform_l(6, 20, 10, 42),
    ]
}

#[test]
fn all_five_algorithms_bit_identical_across_thread_counts() {
    for p in problems() {
        for algo in Algorithm::ALL {
            let seq = run(&p, algo, &opts(1), &NativeEngine::new(&p));
            for threads in [2, 3, 8] {
                let par = run(&p, algo, &opts(threads), &NativeEngine::new(&p));
                assert_bit_identical(
                    &seq,
                    &par,
                    &format!("{} on {} with {} threads", algo.name(), p.name, threads),
                );
            }
        }
    }
}

#[test]
fn auto_thread_mode_bit_identical_to_sequential() {
    // a problem large enough that auto mode actually engages the pool
    let p = synthetic::linreg_increasing_l(9, 50, 50, 43);
    for algo in [Algorithm::Gd, Algorithm::LagWk, Algorithm::LagPs] {
        let seq = run(&p, algo, &opts(1), &NativeEngine::new(&p));
        let auto = run(&p, algo, &opts(0), &NativeEngine::new(&p));
        assert_bit_identical(&seq, &auto, &format!("{} auto-threads", algo.name()));
    }
}

#[test]
fn target_stopping_identical_under_pool() {
    let p = synthetic::linreg_increasing_l(9, 30, 16, 44);
    let mk = |threads| RunOptions {
        max_iters: 5000,
        target_err: Some(1e-9),
        threads,
        ..Default::default()
    };
    for algo in [Algorithm::Gd, Algorithm::LagWk, Algorithm::LagPs] {
        let seq = run(&p, algo, &mk(1), &NativeEngine::new(&p));
        let par = run(&p, algo, &mk(4), &NativeEngine::new(&p));
        assert_eq!(seq.converged_iter, par.converged_iter, "{}", algo.name());
        assert_eq!(seq.uploads_at_target, par.uploads_at_target, "{}", algo.name());
        assert_bit_identical(&seq, &par, &format!("{} with target", algo.name()));
    }
}

#[test]
fn repeated_parallel_runs_are_self_identical() {
    // scheduler nondeterminism must not leak into traces even run-to-run
    let p = synthetic::logreg_uniform_l(7, 22, 9, 45);
    let a = run(&p, Algorithm::LagWk, &opts(4), &NativeEngine::new(&p));
    let b = run(&p, Algorithm::LagWk, &opts(4), &NativeEngine::new(&p));
    assert_bit_identical(&a, &b, "repeat lag-wk 4 threads");
}

#[test]
fn csr_problems_bit_identical_across_thread_counts() {
    // sparse shards go through the CSR kernels on every pool thread; the
    // pooled traces must still match the sequential driver exactly
    for p in [
        synthetic::sparse_linreg(8, 30, 20, 0.08, 46),
        synthetic::sparse_logreg(6, 24, 14, 0.12, 47),
    ] {
        assert!(
            p.workers.iter().all(|s| s.storage.is_csr()),
            "{}: shards must select CSR for this test to bite",
            p.name
        );
        for algo in Algorithm::ALL {
            let seq = run(&p, algo, &opts(1), &NativeEngine::new(&p));
            for threads in [2, 4] {
                let par = run(&p, algo, &opts(threads), &NativeEngine::new(&p));
                assert_bit_identical(
                    &seq,
                    &par,
                    &format!("{} on {} with {} threads", algo.name(), p.name, threads),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Run-level scheduler (experiments::sched): the scheduled grid must be a
// pure function of the specs — bit-identical traces and report JSON for
// every scheduler thread count, with each problem built exactly once.
// ---------------------------------------------------------------------------

/// A Table 5-shaped grid (2 tasks × 2 problems × all 5 algorithms) over
/// CI-sized synthetic problems, in deterministic submission order.
fn grid_specs() -> Vec<RunSpec> {
    let keys = [
        ProblemKey::SynLinregIncreasing { m: 5, n: 20, d: 10, seed: 51 },
        ProblemKey::SynLinregIncreasing { m: 7, n: 18, d: 8, seed: 52 },
        ProblemKey::SynLogregUniform { m: 4, n: 16, d: 9, seed: 53 },
        ProblemKey::SynLogregUniform { m: 6, n: 14, d: 7, seed: 54 },
    ];
    let mut specs = Vec::new();
    for key in keys {
        for algo in Algorithm::ALL {
            specs.push(RunSpec {
                key: key.clone(),
                algo,
                opts: RunOptions {
                    max_iters: 150,
                    target_err: Some(1e-9),
                    record_thetas: true,
                    ..Default::default()
                },
            });
        }
    }
    specs
}

/// Render a grid's traces the way table5 renders its report JSON: task
/// from the key order, uploads-at-target per cell.
fn grid_report_json(traces: &[RunTrace]) -> String {
    let mut uploads = BTreeMap::new();
    for (i, t) in traces.iter().enumerate() {
        let task = if i < 10 { "linreg" } else { "logreg" };
        let mi = (i / 5) % 2;
        uploads.insert((task.to_string(), mi, t.algo.clone()), t.uploads_at_target);
    }
    report::table5_json(&Table5Result { uploads }, &[1, 2]).to_string()
}

#[test]
fn scheduled_grid_bit_identical_across_thread_counts() {
    let seq_ctx = ExpContext { sched_threads: 1, ..Default::default() };
    let seq = seq_ctx.run_specs(grid_specs()).expect("sequential grid");
    assert_eq!(seq.len(), 20);
    let seq_json = grid_report_json(&seq);
    for sched_threads in [2, 0] {
        let ctx = ExpContext { sched_threads, ..Default::default() };
        let par = ctx.run_specs(grid_specs()).expect("scheduled grid");
        for (a, b) in seq.iter().zip(&par) {
            assert_bit_identical(
                a,
                b,
                &format!("{} on {} with sched_threads={sched_threads}", a.algo, a.problem),
            );
        }
        // the rendered report is bitwise identical too
        assert_eq!(seq_json, grid_report_json(&par), "sched_threads={sched_threads}");
        // 4 distinct problem keys → exactly 4 builds, even under
        // concurrent first access from 20 runs
        assert_eq!(ctx.cache.builds(), 4, "sched_threads={sched_threads}");
        assert_eq!(ctx.cache.len(), 4);
    }
}

#[test]
fn scheduled_trace_csv_bytes_match_sequential() {
    // the exact artifact the figures are built from — CSV bytes on disk —
    // must be identical whichever thread count produced the traces
    // (per-process dir: concurrent test invocations must not interleave)
    let dir = std::env::temp_dir().join(format!("lag_sched_csv_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let seq_ctx = ExpContext { sched_threads: 1, ..Default::default() };
    let par_ctx = ExpContext { sched_threads: 0, ..Default::default() };
    let seq = seq_ctx.run_specs(grid_specs()).unwrap();
    let par = par_ctx.run_specs(grid_specs()).unwrap();
    for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
        let pa = dir.join(format!("seq_{i}.csv"));
        let pb = dir.join(format!("par_{i}.csv"));
        a.write_csv(&pa).unwrap();
        b.write_csv(&pb).unwrap();
        assert_eq!(
            std::fs::read(&pa).unwrap(),
            std::fs::read(&pb).unwrap(),
            "trace CSV {i} ({} on {})",
            a.algo,
            a.problem
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn problem_cache_shares_one_arc_per_key() {
    let ctx = ExpContext::default();
    let key = ProblemKey::SynLinregIncreasing { m: 5, n: 20, d: 10, seed: 51 };
    let a = ctx.problem(&key).unwrap();
    let b = ctx.problem(&key).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "same key must return the same Arc<Problem>");
    assert_eq!(ctx.cache.builds(), 1);
    // and the cached build is bitwise the direct build
    let direct = key.build().unwrap();
    assert_eq!(a.loss_star.to_bits(), direct.loss_star.to_bits());
    for (x, y) in a.theta_star.iter().zip(&direct.theta_star) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in a.l_m.iter().zip(&direct.l_m) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn scheduled_single_run_matches_direct_run() {
    // a 1-spec batch keeps its round-level threads option; either way the
    // trace must equal a direct run() of the same spec
    let key = ProblemKey::SynLinregIncreasing { m: 5, n: 20, d: 10, seed: 51 };
    let opts = RunOptions { max_iters: 120, record_thetas: true, ..Default::default() };
    let ctx = ExpContext::default();
    for algo in [Algorithm::Gd, Algorithm::LagWk, Algorithm::NumIag] {
        let sched = ctx
            .run_specs(vec![RunSpec { key: key.clone(), algo, opts: opts.clone() }])
            .unwrap()
            .pop()
            .unwrap();
        let p = key.build().unwrap();
        let direct = run(&p, algo, &opts, &NativeEngine::new(&p));
        assert_bit_identical(&sched, &direct, &format!("{algo:?} scheduled vs direct"));
    }
}

/// A stochastic grid (SGD + both LASG variants on two problems, minibatch
/// and fractional specs) must be bit-identical for every scheduler width —
/// batches are `(seed, worker, iter)`-keyed, so neither the scheduler nor
/// the thread pool can perturb them.
#[test]
fn scheduled_stochastic_grid_bit_identical_across_thread_counts() {
    use lag::grad::BatchSpec;
    let keys = [
        ProblemKey::SynLinregIncreasing { m: 5, n: 20, d: 10, seed: 51 },
        ProblemKey::SynSparseLogreg { m: 4, n: 24, d: 12, density_ppm: 120_000, seed: 55 },
    ];
    let specs = || -> Vec<RunSpec> {
        let mut out = Vec::new();
        for key in &keys {
            for algo in Algorithm::STOCHASTIC {
                for batch in [BatchSpec::Fixed(6), BatchSpec::Fraction(0.4)] {
                    out.push(RunSpec {
                        key: key.clone(),
                        algo,
                        opts: RunOptions {
                            max_iters: 120,
                            record_thetas: true,
                            batch,
                            ..Default::default()
                        },
                    });
                }
            }
        }
        out
    };
    let seq_ctx = ExpContext { sched_threads: 1, ..Default::default() };
    let seq = seq_ctx.run_specs(specs()).expect("sequential stochastic grid");
    assert_eq!(seq.len(), 12);
    for sched_threads in [2, 0] {
        let ctx = ExpContext { sched_threads, ..Default::default() };
        let par = ctx.run_specs(specs()).expect("scheduled stochastic grid");
        for (a, b) in seq.iter().zip(&par) {
            assert_bit_identical(
                a,
                b,
                &format!("{} on {} with sched_threads={sched_threads}", a.algo, a.problem),
            );
        }
    }
}

#[test]
fn storage_format_never_changes_traces() {
    // the other half of the format-selection license (DESIGN.md §8): the
    // *same* problem run with CSR shards and with densified shards must
    // produce bit-identical traces, so the density threshold is purely a
    // performance knob
    use lag::data::ShardStorage;
    let p_csr = synthetic::sparse_linreg(6, 25, 16, 0.1, 48);
    let mut p_dense = p_csr.clone();
    for s in &mut p_dense.workers {
        s.storage = ShardStorage::Dense(s.storage.to_dense());
    }
    for algo in Algorithm::ALL {
        let a = run(&p_csr, algo, &opts(1), &NativeEngine::new(&p_csr));
        let b = run(&p_dense, algo, &opts(1), &NativeEngine::new(&p_dense));
        assert_bit_identical(&a, &b, &format!("{} csr vs dense storage", algo.name()));
        // and the pooled dense run against the sequential CSR run
        let c = run(&p_dense, algo, &opts(3), &NativeEngine::new(&p_dense));
        assert_bit_identical(&a, &c, &format!("{} csr seq vs dense pooled", algo.name()));
    }
}
