//! Bit-determinism suite: the parallel gradient pool must reproduce the
//! sequential driver *exactly* — same `records` (to the bit), same
//! `upload_events`, same iterate sequence — for every algorithm, thread
//! count, and task (DESIGN.md §6).
//!
//! This is what licenses the driver to pick a thread count freely (auto
//! mode): the trace is a pure function of (problem, algorithm, options,
//! seed), never of the host's core count or scheduler.

use lag::coordinator::{run, Algorithm, RunOptions, RunTrace};
use lag::data::{synthetic, Problem};
use lag::grad::NativeEngine;

fn assert_bit_identical(a: &RunTrace, b: &RunTrace, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.k, y.k, "{label}: record k");
        assert_eq!(
            x.obj_err.to_bits(),
            y.obj_err.to_bits(),
            "{label}: obj_err at k={} ({} vs {})",
            x.k,
            x.obj_err,
            y.obj_err
        );
        assert_eq!(x.cum_uploads, y.cum_uploads, "{label}: uploads at k={}", x.k);
        assert_eq!(x.cum_downloads, y.cum_downloads, "{label}: downloads at k={}", x.k);
        assert_eq!(x.cum_grad_evals, y.cum_grad_evals, "{label}: grad_evals at k={}", x.k);
    }
    assert_eq!(a.upload_events, b.upload_events, "{label}: upload events");
    assert_eq!(a.converged_iter, b.converged_iter, "{label}: converged_iter");
    assert_eq!(a.uploads_at_target, b.uploads_at_target, "{label}: uploads_at_target");
    assert_eq!(a.thetas.len(), b.thetas.len(), "{label}: theta count");
    for (k, (ta, tb)) in a.thetas.iter().zip(&b.thetas).enumerate() {
        for (j, (va, vb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{label}: theta[{k}][{j}] {va} vs {vb}"
            );
        }
    }
}

fn opts(threads: usize) -> RunOptions {
    RunOptions {
        max_iters: 120,
        record_thetas: true,
        threads,
        ..Default::default()
    }
}

fn problems() -> Vec<Problem> {
    vec![
        synthetic::linreg_increasing_l(9, 25, 12, 41),
        synthetic::logreg_uniform_l(6, 20, 10, 42),
    ]
}

#[test]
fn all_five_algorithms_bit_identical_across_thread_counts() {
    for p in problems() {
        for algo in Algorithm::ALL {
            let seq = run(&p, algo, &opts(1), &NativeEngine::new(&p));
            for threads in [2, 3, 8] {
                let par = run(&p, algo, &opts(threads), &NativeEngine::new(&p));
                assert_bit_identical(
                    &seq,
                    &par,
                    &format!("{} on {} with {} threads", algo.name(), p.name, threads),
                );
            }
        }
    }
}

#[test]
fn auto_thread_mode_bit_identical_to_sequential() {
    // a problem large enough that auto mode actually engages the pool
    let p = synthetic::linreg_increasing_l(9, 50, 50, 43);
    for algo in [Algorithm::Gd, Algorithm::LagWk, Algorithm::LagPs] {
        let seq = run(&p, algo, &opts(1), &NativeEngine::new(&p));
        let auto = run(&p, algo, &opts(0), &NativeEngine::new(&p));
        assert_bit_identical(&seq, &auto, &format!("{} auto-threads", algo.name()));
    }
}

#[test]
fn target_stopping_identical_under_pool() {
    let p = synthetic::linreg_increasing_l(9, 30, 16, 44);
    let mk = |threads| RunOptions {
        max_iters: 5000,
        target_err: Some(1e-9),
        threads,
        ..Default::default()
    };
    for algo in [Algorithm::Gd, Algorithm::LagWk, Algorithm::LagPs] {
        let seq = run(&p, algo, &mk(1), &NativeEngine::new(&p));
        let par = run(&p, algo, &mk(4), &NativeEngine::new(&p));
        assert_eq!(seq.converged_iter, par.converged_iter, "{}", algo.name());
        assert_eq!(seq.uploads_at_target, par.uploads_at_target, "{}", algo.name());
        assert_bit_identical(&seq, &par, &format!("{} with target", algo.name()));
    }
}

#[test]
fn repeated_parallel_runs_are_self_identical() {
    // scheduler nondeterminism must not leak into traces even run-to-run
    let p = synthetic::logreg_uniform_l(7, 22, 9, 45);
    let a = run(&p, Algorithm::LagWk, &opts(4), &NativeEngine::new(&p));
    let b = run(&p, Algorithm::LagWk, &opts(4), &NativeEngine::new(&p));
    assert_bit_identical(&a, &b, "repeat lag-wk 4 threads");
}

#[test]
fn csr_problems_bit_identical_across_thread_counts() {
    // sparse shards go through the CSR kernels on every pool thread; the
    // pooled traces must still match the sequential driver exactly
    for p in [
        synthetic::sparse_linreg(8, 30, 20, 0.08, 46),
        synthetic::sparse_logreg(6, 24, 14, 0.12, 47),
    ] {
        assert!(
            p.workers.iter().all(|s| s.storage.is_csr()),
            "{}: shards must select CSR for this test to bite",
            p.name
        );
        for algo in Algorithm::ALL {
            let seq = run(&p, algo, &opts(1), &NativeEngine::new(&p));
            for threads in [2, 4] {
                let par = run(&p, algo, &opts(threads), &NativeEngine::new(&p));
                assert_bit_identical(
                    &seq,
                    &par,
                    &format!("{} on {} with {} threads", algo.name(), p.name, threads),
                );
            }
        }
    }
}

#[test]
fn storage_format_never_changes_traces() {
    // the other half of the format-selection license (DESIGN.md §8): the
    // *same* problem run with CSR shards and with densified shards must
    // produce bit-identical traces, so the density threshold is purely a
    // performance knob
    use lag::data::ShardStorage;
    let p_csr = synthetic::sparse_linreg(6, 25, 16, 0.1, 48);
    let mut p_dense = p_csr.clone();
    for s in &mut p_dense.workers {
        s.storage = ShardStorage::Dense(s.storage.to_dense());
    }
    for algo in Algorithm::ALL {
        let a = run(&p_csr, algo, &opts(1), &NativeEngine::new(&p_csr));
        let b = run(&p_dense, algo, &opts(1), &NativeEngine::new(&p_dense));
        assert_bit_identical(&a, &b, &format!("{} csr vs dense storage", algo.name()));
        // and the pooled dense run against the sequential CSR run
        let c = run(&p_dense, algo, &opts(3), &NativeEngine::new(&p_dense));
        assert_bit_identical(&a, &c, &format!("{} csr seq vs dense pooled", algo.name()));
    }
}
