//! Failover suite: hot-standby leader replication under fire
//! (DESIGN.md §14).
//!
//! What this certifies, beyond the chaos suite's single-leader recovery:
//!
//! 1. **Takeover is exact.** A 16-worker run whose primary is killed at
//!    every crash position a replicated round can occupy — before the
//!    record exists, mid disk-append, mid `WalShip` (a torn wire frame),
//!    and after the ack-gated commit — fails over to the standby and
//!    still produces a final trace (records to the f64 bit, upload
//!    events, final iterate) identical to an uninterrupted single-leader
//!    run, scheduled membership churn straddling the failover included.
//! 2. **The takeover boundary is deterministic.** Because the primary
//!    gates every commit on the standby's ack, the promotion round is a
//!    function of the crash point alone: `BeforeWal(k)`/`TornWal(k,_)`/
//!    `MidShip(k,_)` promote at `k-1`, `AfterWal(k)` at `k` — pinned
//!    exactly, not bounded.
//! 3. **Workers find the standby on their own.** The fleet learns the
//!    failover address from `Assign`, rides out the primary's death
//!    through its reconnect backoff, and re-runs admission against the
//!    promoted standby with the cached-gradient handoff — no external
//!    coordination.
//! 4. **Corruption dies at the CRC.** A byte flipped inside a shipped
//!    record kills the standby at the frame trailer — counted, never
//!    replayed — and the primary, after the ack gate declares that
//!    standby dead, detaches it and carries the run to convergence.
//!
//! CI runs this with `cargo test --release --test failover`.

use lag::coordinator::{
    run_service, serve_worker, Algorithm, CrashPoint, FaultConfig, FaultPlan, IterRecord,
    RunOptions, RunTrace, ServiceOptions, ServiceStats, WireMsg, WorkerConfig, WorkerExit,
};
use lag::data::{synthetic, Problem};
use lag::util::BackoffPolicy;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Per-case wall budget: a wedged failover must fail loudly, not hang
/// the job until the CI runner's timeout.
const WALL_BUDGET: Duration = Duration::from_secs(120);

fn sopts() -> ServiceOptions {
    ServiceOptions {
        join_timeout: Duration::from_secs(60),
        round_timeout: Duration::from_secs(60),
        heartbeat_timeout: Duration::from_secs(60),
        tick: Duration::from_millis(1),
        ..Default::default()
    }
}

/// Scheduled churn straddling every crash point in the matrix: shard 2
/// is dropped before the earliest failover and re-admitted after it
/// (the hold must survive the takeover), shard 6 churns entirely on the
/// post-failover side. The same plan drives the primary, the standby,
/// and the uninterrupted reference — rounds at or before the takeover
/// fire on the primary (and reach the standby replayed from the WAL),
/// later rounds fire on whichever leader is live.
fn churn() -> FaultPlan {
    FaultPlan {
        drop_after: vec![(5, 2), (25, 6)],
        admit_at: vec![(10, 2), (28, 6)],
        ..Default::default()
    }
}

fn record_sig(records: &[IterRecord]) -> Vec<(usize, u64, u64, u64)> {
    records.iter().map(|r| (r.k, r.obj_err.to_bits(), r.cum_uploads, r.cum_downloads)).collect()
}

fn theta_bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A preferred-shard fleet that survives a leader failover: each worker
/// remembers the standby address its `Assign`s advertised and, when a
/// session dies past the reconnect budget, retargets to the other
/// incarnation — the client-side half of DESIGN.md §14.
fn spawn_fleet<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    p: &'env Problem,
    primary: &'env str,
    done: &'env AtomicBool,
) {
    for s in 0..p.m() {
        scope.spawn(move || {
            let cfg = WorkerConfig {
                preferred: Some(s),
                heartbeat_interval: Duration::from_millis(20),
                leader_timeout: Duration::from_secs(60),
                // a deep budget with a small cap: one call rides out both
                // an admission hold (`Reject`s burn retries) and the
                // connect storm against a freshly dead primary
                reconnect: BackoffPolicy {
                    base: Duration::from_millis(2),
                    cap: Duration::from_millis(20),
                    max_retries: 40,
                    seed: s as u64 + 1,
                },
                ..Default::default()
            };
            let mut target = primary.to_string();
            let mut standby: Option<String> = None;
            while !done.load(Ordering::SeqCst) {
                match serve_worker(&target, p, &cfg) {
                    Ok(o) => {
                        if o.standby.is_some() {
                            standby = o.standby.clone();
                        }
                        if o.exit == WorkerExit::Shutdown {
                            break;
                        }
                    }
                    Err(_) => {
                        // budget exhausted against this incarnation: try
                        // the other one (primary ↔ standby)
                        if let Some(sb) = &standby {
                            target = if target == *sb { primary.to_string() } else { sb.clone() };
                        }
                    }
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        });
    }
}

/// One uninterrupted single-leader run over the same fleet and churn
/// plan (the reference every failover case is byte-compared against).
fn run_clean(p: &Problem, opts: &RunOptions, faults: &FaultPlan) -> (RunTrace, ServiceStats) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let leader = scope.spawn(|| {
            let out = run_service(listener, p, Algorithm::LagWk, opts, &sopts(), faults);
            done.store(true, Ordering::SeqCst);
            out.unwrap()
        });
        spawn_fleet(scope, p, &addr, &done);
        leader.join().unwrap()
    })
}

/// The headline failover test: for each crash position a replicated
/// round can die at — including mid-`WalShip`, the torn wire frame —
/// the primary is killed, the fleet fails over through the advertised
/// standby address, the standby promotes at its last fully acked round
/// boundary, and the completed run's trace is byte-identical to the
/// uninterrupted reference. The promotion round is asserted exactly:
/// ack-gated commits make it a deterministic function of the crash
/// point.
#[test]
fn failover_is_bit_identical_at_every_crash_point() {
    let m = 16;
    let p = synthetic::linreg_increasing_l(m, 10, 4, 2030);
    let opts = RunOptions { max_iters: 30, record_every: 1, ..Default::default() };
    let faults = churn();
    let (clean_trace, clean_stats) = run_clean(&p, &opts, &faults);
    assert_eq!(clean_trace.records.last().unwrap().k, opts.max_iters);

    // (crash point, promotion round it must pin, needs a disk WAL)
    let cases = [
        (CrashPoint::BeforeWal(8), 7u64, false),
        (CrashPoint::TornWal(12, 9), 11, true),
        (CrashPoint::MidShip(15, 9), 14, false),
        (CrashPoint::AfterWal(20), 20, false),
    ];
    for (crash, takeover, needs_wal) in cases {
        let wal = needs_wal.then(|| {
            let path = std::env::temp_dir().join("lag_failover_torn.wal");
            let _ = std::fs::remove_file(&path);
            path
        });
        let primary_lis = TcpListener::bind("127.0.0.1:0").unwrap();
        let primary_addr = primary_lis.local_addr().unwrap().to_string();
        let standby_lis = TcpListener::bind("127.0.0.1:0").unwrap();
        let standby_addr = standby_lis.local_addr().unwrap().to_string();
        let psopts = ServiceOptions {
            crash: Some(crash),
            standby_addr: Some(standby_addr.clone()),
            wal: wal.clone(),
            ..sopts()
        };
        let ssopts = ServiceOptions { standby_of: Some(primary_addr.clone()), ..sopts() };
        let done = AtomicBool::new(false);
        let p = &p;
        let opts = &opts;
        let faults = &faults;
        let t0 = Instant::now();
        let (perr, (trace, stats)) = std::thread::scope(|scope| {
            let primary = scope.spawn(|| {
                run_service(primary_lis, p, Algorithm::LagWk, opts, &psopts, faults)
            });
            let standby = scope.spawn(|| {
                let out = run_service(standby_lis, p, Algorithm::LagWk, opts, &ssopts, faults);
                done.store(true, Ordering::SeqCst);
                out
            });
            spawn_fleet(scope, p, &primary_addr, &done);
            (primary.join().unwrap().unwrap_err(), standby.join().unwrap().unwrap())
        });
        let elapsed = t0.elapsed();
        assert!(elapsed < WALL_BUDGET, "{crash:?}: failover blew the wall budget: {elapsed:?}");
        assert!(
            perr.to_string().contains("injected crash"),
            "{crash:?}: primary died of the wrong cause: {perr:#}"
        );

        // the takeover boundary, pinned exactly
        assert_eq!(stats.promotions, 1, "{crash:?}");
        assert_eq!(stats.failover_round, takeover, "{crash:?}: wrong promotion round");
        assert_eq!(
            stats.wal_shipped_records,
            takeover,
            "{crash:?}: replayed records must match the promotion round"
        );

        // bit-identical survival: every record, every upload event, the
        // final iterate — churn straddling the takeover included
        assert_eq!(trace.records.last().unwrap().k, opts.max_iters, "{crash:?}");
        assert_eq!(record_sig(&trace.records), record_sig(&clean_trace.records), "{crash:?}");
        assert_eq!(trace.upload_events, clean_trace.upload_events, "{crash:?}");
        assert_eq!(
            theta_bits(&stats.final_theta),
            theta_bits(&clean_stats.final_theta),
            "{crash:?}"
        );
        if let Some(path) = wal {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// The replication frame (`WalShip`) index the proxy corrupts: 0 is the
/// WAL header, i ≥ 1 is round i — so flipping index 4 leaves rounds 1–3
/// cleanly replayed and kills the standby on round 4's record.
const CORRUPT_SHIP: u32 = 4;

/// Man-in-the-middle proxy for the replication channel: standby→primary
/// bytes (`Promote`, `WalAck`s) pass verbatim; primary→standby bytes are
/// length-parsed into frames and the `CORRUPT_SHIP`-th `WalShip` gets
/// one payload byte flipped, so the outer CRC trailer must catch it at
/// the standby.
fn flipping_proxy(listener: TcpListener, primary: String) {
    let Ok((standby_side, _)) = listener.accept() else { return };
    let Ok(primary_side) = TcpStream::connect(primary.as_str()) else { return };
    let mut up_src = standby_side.try_clone().unwrap();
    let mut up_dst = primary_side.try_clone().unwrap();
    let up = std::thread::spawn(move || {
        let mut b = [0u8; 4096];
        loop {
            match up_src.read(&mut b) {
                Ok(0) | Err(_) => break,
                Ok(n) => {
                    if up_dst.write_all(&b[..n]).is_err() {
                        break;
                    }
                }
            }
        }
        let _ = up_dst.shutdown(std::net::Shutdown::Both);
    });
    let mut down_src = primary_side;
    let mut down_dst = standby_side;
    let ship_tag = WireMsg::WalShip { k: 0, rec: Vec::new() }.encode()[4];
    let mut buf: Vec<u8> = Vec::new();
    let mut ships = 0u32;
    let mut chunk = [0u8; 65536];
    loop {
        let n = match down_src.read(&mut chunk) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        buf.extend_from_slice(&chunk[..n]);
        // forward whole frames only: [len u32 LE][tag][payload][crc u32
        // LE], the length prefix covering tag + payload
        let mut fwd = 0usize;
        while buf.len() - fwd >= 4 {
            let len = u32::from_le_bytes(buf[fwd..fwd + 4].try_into().unwrap()) as usize;
            let total = 4 + len + 4;
            if buf.len() - fwd < total {
                break;
            }
            if buf[fwd + 4] == ship_tag {
                if ships == CORRUPT_SHIP {
                    buf[fwd + 6] ^= 0xFF;
                }
                ships += 1;
            }
            fwd += total;
        }
        if down_dst.write_all(&buf[..fwd]).is_err() {
            break;
        }
        buf.drain(..fwd);
    }
    let _ = down_dst.shutdown(std::net::Shutdown::Both);
    let _ = up.join();
}

/// Corruption containment on the replication channel: a byte flipped
/// inside the fifth `WalShip` frame, while the standby acks under seeded
/// ack delays, must die at the standby's CRC — the standby errors out
/// after exactly the three cleanly replayed rounds, never applying the
/// poisoned one — and the primary, its ack gate left hanging, declares
/// the standby dead, detaches it, and finishes the run solo, still
/// converging.
#[test]
fn corrupt_wal_ship_dies_at_the_crc_and_the_primary_survives() {
    let m = 8;
    let p = synthetic::linreg_increasing_l(m, 8, 4, 2031);
    let opts = RunOptions { max_iters: 30, record_every: 1, ..Default::default() };

    let primary_lis = TcpListener::bind("127.0.0.1:0").unwrap();
    let primary_addr = primary_lis.local_addr().unwrap().to_string();
    let standby_lis = TcpListener::bind("127.0.0.1:0").unwrap();
    let standby_addr = standby_lis.local_addr().unwrap().to_string();
    let proxy_lis = TcpListener::bind("127.0.0.1:0").unwrap();
    let proxy_addr = proxy_lis.local_addr().unwrap().to_string();

    let psopts = ServiceOptions {
        standby_addr: Some(standby_addr.clone()),
        // a hanging ack should detach the dead standby promptly, not
        // stall the round for the default five seconds
        ack_timeout: Duration::from_millis(1000),
        ..sopts()
    };
    // the standby attaches through the byte-flipping proxy, acking under
    // seeded delays (timing-only: the gate waits, the trace is unchanged)
    let ssopts = ServiceOptions { standby_of: Some(proxy_addr.clone()), ..sopts() };
    let ack_faults = FaultPlan {
        io: FaultConfig {
            seed: 7,
            short_read: 0.0,
            short_write: 0.0,
            corrupt: 0.0,
            reset: 0.0,
            delay: 0.0,
            ack_delay: 0.3,
        },
        ..Default::default()
    };

    let done = AtomicBool::new(false);
    let p = &p;
    let opts = &opts;
    let t0 = Instant::now();
    let ((trace, stats), serr) = std::thread::scope(|scope| {
        scope.spawn(|| flipping_proxy(proxy_lis, primary_addr.clone()));
        let primary = scope.spawn(|| {
            let no_faults = FaultPlan::default();
            let out = run_service(primary_lis, p, Algorithm::LagWk, opts, &psopts, &no_faults);
            done.store(true, Ordering::SeqCst);
            out
        });
        let standby = scope.spawn(|| {
            run_service(standby_lis, p, Algorithm::LagWk, opts, &ssopts, &ack_faults)
        });
        spawn_fleet(scope, p, &primary_addr, &done);
        (primary.join().unwrap().unwrap(), standby.join().unwrap().unwrap_err())
    });
    let elapsed = t0.elapsed();
    assert!(elapsed < WALL_BUDGET, "corruption run blew the wall budget: {elapsed:?}");

    // the corrupt record died at the CRC: the standby reports exactly the
    // three rounds it replayed cleanly — the poisoned fourth was never
    // applied
    let msg = format!("{serr:#}");
    assert!(
        msg.contains("replication stream corrupt after 3 replayed rounds"),
        "standby died of the wrong cause: {msg}"
    );

    // the primary detached the dead standby and finished the run solo —
    // no promotion, shipping stopped at the kill, and the ack gate's lag
    // accounting engaged
    assert_eq!(trace.records.last().unwrap().k, opts.max_iters);
    let first = trace.records.first().unwrap().obj_err;
    let last = trace.records.last().unwrap().obj_err;
    assert!(last < first, "objective did not decrease: {first} -> {last}");
    assert_eq!(stats.promotions, 0);
    assert_eq!(stats.failover_round, 0);
    assert!(
        stats.wal_shipped_records >= CORRUPT_SHIP as u64,
        "only {} records shipped before the kill",
        stats.wal_shipped_records
    );
    assert!(stats.ack_lag_max >= 1, "the ack gate never measured an outstanding record");
}
