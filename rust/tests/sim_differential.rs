//! Differential suite pinning the discrete-event fleet simulator to the
//! real implementations (DESIGN.md §15). The simulator is useful only if
//! it is *not* a second implementation that can drift, so every claim is
//! tested against the code that defines the truth:
//!
//! 1. **Sim ≡ sequential driver.** With zero network delay the sim's
//!    trace — records to the f64 bit, upload events, every recorded
//!    iterate — must be byte-identical to `coordinator::run` for all
//!    eight algorithms (the paper's five full-batch methods and the
//!    LASG stochastic family).
//! 2. **Sim ≡ service round semantics.** On the same `FaultPlan`, the
//!    sim must reproduce the socket service's round-boundary behavior
//!    exactly: records, upload events, final iterate, eviction causes,
//!    forced skips, joins.
//! 3. **Scale determinism.** Two identical-seed runs at
//!    `LAG_SIM_WORKERS` (default 2000; CI runs 100000 in release) must
//!    byte-compare equal, and permuting worker *timing identities*
//!    (compute-speed rotation) must not change any aggregate trajectory
//!    — timing may move, math may not.
//! 4. **Event-queue properties.** Equal-timestamp events never reorder
//!    across runs, the virtual clock is monotone, and no event is lost
//!    or double-delivered under interleaved cancel/reschedule.
//!
//! CI runs this with
//! `LAG_SIM_WORKERS=100000 cargo test --release --test sim_differential`.

mod common;

use common::{drive, env_fleet, record_sig, sopts, theta_bits, WALL_BUDGET};
use lag::coordinator::{run, Algorithm, EvictCause, FaultPlan, RunOptions};
use lag::data::{synthetic, Problem, Task};
use lag::grad::{BatchSpec, NativeEngine};
use lag::sim::{simulate, ComputeSpec, EventQueue, NetSpec, SimOptions};
use lag::util::rng::Rng;
use std::time::Instant;

/// Differential fleet size: `LAG_SIM_WORKERS`, default 2000 (debug-
/// friendly); the CI sim job sets 100000 in release.
fn sim_fleet_size() -> usize {
    env_fleet("LAG_SIM_WORKERS", 2000, 64)
}

/// A heterogeneous fleet problem that stays numerically sane at any M:
/// per-worker smoothness log-spaced over one decade (the `Increasing`
/// profile overflows at large M, so big fleets use explicit targets).
fn spread_problem(m: usize, n: usize, d: usize, seed: u64) -> Problem {
    let denom = (m - 1).max(1) as f64;
    let targets: Vec<f64> =
        (0..m).map(|i| 10f64.powf(i as f64 / denom)).collect();
    synthetic::synthetic_with_targets(Task::LinReg, &targets, n, d, seed)
}

/// Every algorithm the sequential driver implements, with the batch spec
/// the stochastic family needs.
fn all_algorithms() -> Vec<(Algorithm, BatchSpec)> {
    vec![
        (Algorithm::Gd, BatchSpec::Full),
        (Algorithm::LagWk, BatchSpec::Full),
        (Algorithm::LagPs, BatchSpec::Full),
        (Algorithm::CycIag, BatchSpec::Full),
        (Algorithm::NumIag, BatchSpec::Full),
        (Algorithm::Sgd, BatchSpec::Fixed(4)),
        (Algorithm::LasgWk, BatchSpec::Fixed(4)),
        (Algorithm::LasgPs, BatchSpec::Fixed(4)),
    ]
}

// ---------------------------------------------------------------------
// (a) zero-delay sim ≡ sequential run.rs, all algorithms
// ---------------------------------------------------------------------

#[test]
fn zero_delay_sim_is_byte_identical_to_sequential_driver() {
    let p = synthetic::linreg_increasing_l(16, 8, 6, 9001);
    for (algo, batch) in all_algorithms() {
        let opts = RunOptions {
            max_iters: 80,
            record_every: 1,
            record_thetas: true,
            threads: 1,
            batch,
            ..Default::default()
        };
        let seq = run(&p, algo, &opts, &NativeEngine::new(&p));
        let rep = simulate(&p, algo, &opts, &SimOptions::default(), &NativeEngine::new(&p))
            .unwrap();
        // records carry the objective (f64 bits), uploads, downloads,
        // gradient evaluations — every trigger decision is visible here
        assert_eq!(rep.trace.records, seq.records, "{algo:?}: records drifted");
        assert_eq!(
            record_sig(&rep.trace.records),
            record_sig(&seq.records),
            "{algo:?}: objective bits drifted"
        );
        assert_eq!(rep.trace.upload_events, seq.upload_events, "{algo:?}: uploads drifted");
        assert_eq!(rep.trace.thetas.len(), seq.thetas.len());
        for (ka, (a, b)) in rep.trace.thetas.iter().zip(&seq.thetas).enumerate() {
            assert_eq!(theta_bits(a), theta_bits(b), "{algo:?}: iterate {ka} drifted");
        }
        assert_eq!(rep.trace.converged_iter, seq.converged_iter);
        assert_eq!(rep.trace.alpha, seq.alpha);
    }
}

/// The equivalence must hold however slow the modeled fleet is: network
/// and compute models may move virtual time only.
#[test]
fn loaded_network_and_compute_models_never_touch_the_math() {
    let p = synthetic::linreg_increasing_l(16, 8, 6, 9001);
    let opts =
        RunOptions { max_iters: 60, record_every: 1, threads: 1, ..Default::default() };
    let seq = run(&p, Algorithm::LagPs, &opts, &NativeEngine::new(&p));
    for net in [
        NetSpec::Constant { latency_ns: 200_000, gbps: 0.1 },
        NetSpec::SharedLeader { latency_ns: 50_000, gbps: 1.0 },
        NetSpec::PerLink { latency_ns: 100_000, gbps: 0.5, spread: 0.9, seed: 5 },
    ] {
        let sopts_sim = SimOptions {
            net,
            compute: ComputeSpec::LogNormal { median_ns: 3_000_000, sigma: 1.2, seed: 8 },
            sim_seed: 17,
            ..Default::default()
        };
        let rep =
            simulate(&p, Algorithm::LagPs, &opts, &sopts_sim, &NativeEngine::new(&p)).unwrap();
        assert_eq!(record_sig(&rep.trace.records), record_sig(&seq.records));
        assert_eq!(rep.trace.upload_events, seq.upload_events);
        assert!(rep.stats.sim_ns > 0, "{net:?}: a loaded fleet must take virtual time");
    }
}

// ---------------------------------------------------------------------
// (b) sim ≡ service.rs round-boundary semantics on the same FaultPlan
// ---------------------------------------------------------------------

#[test]
fn sim_matches_service_round_semantics_on_the_same_fault_plan() {
    let m = 12;
    let p = synthetic::linreg_increasing_l(m, 8, 6, 9002);
    let opts = RunOptions { max_iters: 30, record_every: 1, ..Default::default() };
    // straggle windows plus a scheduled drop/rejoin, all boundary-aligned
    let faults = FaultPlan {
        straggle: vec![(5, 3, 8), (14, 9, 17)],
        drop_after: vec![(10, 6)],
        admit_at: vec![(15, 6)],
        ..Default::default()
    };

    let t0 = Instant::now();
    let (svc_trace, svc_stats) = drive(&p, Algorithm::LagWk, &opts, &sopts(), &faults);
    assert!(t0.elapsed() < WALL_BUDGET, "service run blew the wall budget");

    let sopts_sim = SimOptions { faults: faults.clone(), ..Default::default() };
    let rep = simulate(&p, Algorithm::LagWk, &opts, &sopts_sim, &NativeEngine::new(&p)).unwrap();

    assert_eq!(record_sig(&rep.trace.records), record_sig(&svc_trace.records));
    assert_eq!(rep.trace.upload_events, svc_trace.upload_events);
    assert_eq!(theta_bits(&rep.stats.final_theta), theta_bits(&svc_stats.final_theta));
    assert_eq!(rep.stats.evictions, svc_stats.evictions);
    assert_eq!(rep.stats.eviction_causes, svc_stats.eviction_causes);
    assert_eq!(rep.stats.forced_skips, svc_stats.forced_skips);
    assert_eq!(rep.stats.joins, svc_stats.joins);
    assert_eq!(rep.stats.retries, svc_stats.retries);
    assert_eq!(rep.stats.eviction_causes, vec![(6, EvictCause::Scheduled)]);
}

/// Same contract for plain GD (rhs = 0): the upload-event structure is
/// then decided entirely by the fault machinery, isolating it from the
/// trigger.
#[test]
fn sim_matches_service_under_gd_with_straggle_windows() {
    let m = 8;
    let p = synthetic::linreg_increasing_l(m, 8, 5, 9004);
    let opts = RunOptions { max_iters: 24, record_every: 1, ..Default::default() };
    let faults =
        FaultPlan { straggle: vec![(4, 1, 7), (4, 5, 6), (12, 1, 15)], ..Default::default() };

    let (svc_trace, svc_stats) = drive(&p, Algorithm::Gd, &opts, &sopts(), &faults);
    let sopts_sim = SimOptions { faults: faults.clone(), ..Default::default() };
    let rep = simulate(&p, Algorithm::Gd, &opts, &sopts_sim, &NativeEngine::new(&p)).unwrap();

    assert_eq!(record_sig(&rep.trace.records), record_sig(&svc_trace.records));
    assert_eq!(rep.trace.upload_events, svc_trace.upload_events);
    assert_eq!(theta_bits(&rep.stats.final_theta), theta_bits(&svc_stats.final_theta));
    assert_eq!(rep.stats.forced_skips, svc_stats.forced_skips);
    let expected: u64 = [(4u64, 7u64), (4, 6), (12, 15)].iter().map(|&(f, r)| r - f).sum();
    assert_eq!(rep.stats.forced_skips, expected);
}

// ---------------------------------------------------------------------
// (c) scale: identical seeds byte-compare equal; timing identities
//     cannot change trajectories
// ---------------------------------------------------------------------

#[test]
fn identical_seed_large_fleet_runs_byte_compare_equal() {
    let m = sim_fleet_size();
    let p = spread_problem(m, 4, 6, 9003);
    let opts = RunOptions { max_iters: 25, record_every: 1, threads: 1, ..Default::default() };
    let sopts_sim = SimOptions {
        net: NetSpec::SharedLeader { latency_ns: 20_000, gbps: 40.0 },
        compute: ComputeSpec::LogNormal { median_ns: 1_000_000, sigma: 0.7, seed: 21 },
        sim_seed: 99,
        ..Default::default()
    };
    let t0 = Instant::now();
    let a = simulate(&p, Algorithm::LagWk, &opts, &sopts_sim, &NativeEngine::new(&p)).unwrap();
    let b = simulate(&p, Algorithm::LagWk, &opts, &sopts_sim, &NativeEngine::new(&p)).unwrap();
    assert!(
        t0.elapsed() < WALL_BUDGET,
        "two {m}-worker sim runs blew the wall budget: {:?}",
        t0.elapsed()
    );

    assert_eq!(record_sig(&a.trace.records), record_sig(&b.trace.records));
    assert_eq!(a.trace.upload_events, b.trace.upload_events);
    assert_eq!(theta_bits(&a.stats.final_theta), theta_bits(&b.stats.final_theta));
    // the timing layer is deterministic too: virtual clock, event count,
    // modeled wire volume all byte-compare
    assert_eq!(a.stats.sim_ns, b.stats.sim_ns);
    assert_eq!(a.stats.events_processed, b.stats.events_processed);
    assert_eq!(a.stats.bytes_up, b.stats.bytes_up);
    assert_eq!(a.stats.bytes_down, b.stats.bytes_down);
    assert_eq!(a.stats.cluster_compute_ns, b.stats.cluster_compute_ns);
    assert!(a.stats.sim_ns > 0);
}

#[test]
fn permuting_timing_identities_cannot_change_aggregate_trajectories() {
    let m = sim_fleet_size();
    let p = spread_problem(m, 4, 6, 9003);
    let opts = RunOptions { max_iters: 20, record_every: 1, threads: 1, ..Default::default() };
    let base_sim = SimOptions {
        net: NetSpec::PerLink { latency_ns: 50_000, gbps: 5.0, spread: 0.6, seed: 31 },
        compute: ComputeSpec::LogNormal { median_ns: 500_000, sigma: 0.9, seed: 32 },
        sim_seed: 7,
        ..Default::default()
    };
    let base = simulate(&p, Algorithm::LagPs, &opts, &base_sim, &NativeEngine::new(&p)).unwrap();
    for rot in [1, m / 3 + 1] {
        let rotated = SimOptions { compute_rotation: rot, ..base_sim.clone() };
        let r = simulate(&p, Algorithm::LagPs, &opts, &rotated, &NativeEngine::new(&p)).unwrap();
        // timing identities moved; the math must not notice
        assert_eq!(
            record_sig(&r.trace.records),
            record_sig(&base.trace.records),
            "rotation {rot} changed the trajectory"
        );
        assert_eq!(r.trace.upload_events, base.trace.upload_events);
        assert_eq!(theta_bits(&r.stats.final_theta), theta_bits(&base.stats.final_theta));
    }
}

// ---------------------------------------------------------------------
// (d) event-queue properties
// ---------------------------------------------------------------------

/// Equal-timestamp delivery order is a pure function of the queue seed —
/// across independent queue instances and regardless of how many distinct
/// timestamps surround the collisions.
#[test]
fn equal_timestamp_events_never_reorder_across_runs() {
    let drain = |seed: u64| -> Vec<(u64, usize)> {
        let mut q = EventQueue::new(seed);
        // 400 events over 40 timestamps: ~10-way collisions everywhere
        for i in 0..400usize {
            q.schedule((i % 40) as u64, i);
        }
        let mut out = Vec::new();
        while let Some(ev) = q.pop() {
            out.push(ev);
        }
        out
    };
    let a = drain(123);
    let b = drain(123);
    assert_eq!(a, b, "same seed must replay the identical delivery order");
    assert_ne!(
        drain(124),
        a,
        "a different seed must break ties differently (not insertion order)"
    );
    // within each timestamp the order is seed-chosen, but time still
    // dominates: the (time, …) key is globally sorted
    assert!(a.windows(2).all(|w| w[0].0 <= w[1].0));
}

/// Randomized interleaving of schedule/cancel/reschedule/pop: whatever
/// the interleaving, the clock is monotone and exactly the live events
/// are delivered — none lost, none duplicated.
#[test]
fn queue_never_loses_events_under_interleaved_cancel_reschedule() {
    use std::collections::HashMap;

    for trial in 0..20u64 {
        let mut rng = Rng::new(0xD15C_0000 + trial);
        let mut q: EventQueue<u64> = EventQueue::new(trial);
        // payload -> live event id; every payload scheduled exactly once
        let mut live: HashMap<u64, u64> = HashMap::new();
        let mut delivered: Vec<u64> = Vec::new();
        let mut next_payload = 0u64;
        let mut last_time = 0u64;
        for _ in 0..600 {
            match rng.next_u64() % 5 {
                // schedule a fresh payload at a random future time
                0 | 1 => {
                    let at = q.now() + rng.next_u64() % 50;
                    let id = q.schedule(at, next_payload);
                    live.insert(next_payload, id);
                    next_payload += 1;
                }
                // cancel a random live event
                2 => {
                    if let Some(&payload) = live.keys().next() {
                        let id = live.remove(&payload).unwrap();
                        assert!(q.cancel(id), "live event refused cancellation");
                    }
                }
                // reschedule a random live event to a new future time
                3 => {
                    if let Some(&payload) = live.keys().next() {
                        let id = live[&payload];
                        let at = q.now() + rng.next_u64() % 50;
                        let new_id = q.reschedule(id, at, payload);
                        live.insert(payload, new_id);
                    }
                }
                // deliver one event
                _ => {
                    if let Some((at, payload)) = q.pop() {
                        assert!(at >= last_time, "virtual clock went backwards");
                        last_time = at;
                        assert!(
                            live.remove(&payload).is_some(),
                            "delivered a cancelled or duplicate event: {payload}"
                        );
                        delivered.push(payload);
                    }
                }
            }
        }
        // drain: everything still live must arrive exactly once
        while let Some((at, payload)) = q.pop() {
            assert!(at >= last_time);
            last_time = at;
            assert!(live.remove(&payload).is_some(), "lost track of {payload}");
            delivered.push(payload);
        }
        assert!(live.is_empty(), "trial {trial}: {} events never delivered", live.len());
        assert!(q.is_empty());
        // no payload delivered twice
        let mut seen = delivered.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), delivered.len(), "trial {trial}: duplicate delivery");
    }
}
