//! Property-based tests on coordinator invariants (DESIGN.md §5), using the
//! in-repo deterministic RNG to sweep randomized problem instances — the
//! offline crate universe has no proptest, so the sweeps are explicit.

use lag::coordinator::lyapunov::{analysis_alpha, lyapunov_values};
use lag::coordinator::{run, Algorithm, RunOptions};
use lag::data::{synthetic, Problem, Task};
use lag::grad::{worker_grad, NativeEngine};
use lag::linalg::{axpy, norm};
use lag::util::Rng;

fn random_problem(rng: &mut Rng) -> Problem {
    let m = 2 + rng.below(6);
    let n = 10 + rng.below(30);
    let d = 2 + rng.below(12);
    let task_logreg = rng.uniform() < 0.5;
    let seed = rng.next_u64();
    if task_logreg {
        synthetic::synthetic_problem(
            Task::LogReg { lam: 1e-3 },
            synthetic::LProfile::Increasing,
            m,
            n,
            d,
            seed,
        )
    } else {
        synthetic::synthetic_problem(
            Task::LinReg,
            synthetic::LProfile::Increasing,
            m,
            n,
            d,
            seed,
        )
    }
}

/// Invariant (i): the server's aggregate equals Σ_m ∇L_m(θ̂_m) — the lazy
/// recursion (4) never drifts (up to fp accumulation).
#[test]
fn prop_aggregate_equals_sum_of_cached_gradients() {
    let mut rng = Rng::new(101);
    for case in 0..8 {
        let p = random_problem(&mut rng);
        for algo in [Algorithm::LagWk, Algorithm::LagPs, Algorithm::CycIag] {
            let opts = RunOptions {
                max_iters: 60 + rng.below(120),
                record_thetas: true,
                seed: rng.next_u64(),
                ..Default::default()
            };
            let e = NativeEngine::new(&p);
            let t = run(&p, algo, &opts, &e);
            // reconstruct Σ cached gradients from the upload events
            let mut agg = vec![0.0; p.d];
            let mut contributed = 0;
            for (mi, evs) in t.upload_events.iter().enumerate() {
                if let Some(&last_k) = evs.last() {
                    let theta_hat = &t.thetas[last_k - 1];
                    let (g, _) = worker_grad(p.task, &p.workers[mi], theta_hat);
                    axpy(1.0, &g, &mut agg);
                    contributed += 1;
                }
            }
            if contributed < p.m() {
                continue; // some worker never uploaded (possible for IAG short runs)
            }
            // compare against the actual last step the server took
            let n = t.thetas.len();
            let step: Vec<f64> = t.thetas[n - 2]
                .iter()
                .zip(&t.thetas[n - 1])
                .map(|(prev, cur)| (prev - cur) / t.alpha)
                .collect();
            let diff: f64 = step.iter().zip(&agg).map(|(a, b)| (a - b).abs()).sum();
            assert!(
                diff <= 1e-7 * (1.0 + norm(&agg)),
                "case {case} {:?}: aggregate drift {diff}",
                algo
            );
        }
    }
}

/// Invariant (ii): LAG-WK with ξ = 0 reproduces GD bit-for-bit.
#[test]
fn prop_zero_xi_reduces_to_gd() {
    let mut rng = Rng::new(202);
    for _ in 0..6 {
        let p = random_problem(&mut rng);
        let opts = RunOptions { max_iters: 40, wk_xi: 0.0, ..Default::default() };
        let gd = run(&p, Algorithm::Gd, &opts, &NativeEngine::new(&p));
        let wk = run(&p, Algorithm::LagWk, &opts, &NativeEngine::new(&p));
        assert_eq!(gd.total_uploads(), wk.total_uploads());
        for (a, b) in gd.records.iter().zip(&wk.records) {
            assert_eq!(a.obj_err.to_bits(), b.obj_err.to_bits(), "k={}", a.k);
        }
    }
}

/// Invariant (iii): per-iteration uploads never exceed GD's M, and LAG's
/// total communication is ≤ GD's for the same iteration count.
#[test]
fn prop_lag_upload_budget_bounded_by_gd() {
    let mut rng = Rng::new(303);
    for _ in 0..8 {
        let p = random_problem(&mut rng);
        let iters = 30 + rng.below(100);
        let opts = RunOptions { max_iters: iters, ..Default::default() };
        for algo in [Algorithm::LagWk, Algorithm::LagPs] {
            let t = run(&p, algo, &opts, &NativeEngine::new(&p));
            assert!(t.total_uploads() <= (iters * p.m()) as u64);
            // per-worker: at most one upload per iteration
            for evs in &t.upload_events {
                for w in evs.windows(2) {
                    assert!(w[1] > w[0], "duplicate upload in one iteration");
                }
            }
        }
    }
}

/// Invariant (iv): the Lyapunov function (16) is non-increasing under the
/// analysis parameters (19), for random problems and both LAG rules.
#[test]
fn prop_lyapunov_nonincreasing() {
    let mut rng = Rng::new(404);
    for _ in 0..5 {
        let p = random_problem(&mut rng);
        let d_hist = 10;
        let xi = 0.03 + 0.05 * rng.uniform(); // < 1/D
        let alpha = analysis_alpha(d_hist, xi, p.l_total);
        for (algo, is_wk) in [(Algorithm::LagWk, true), (Algorithm::LagPs, false)] {
            let opts = RunOptions {
                max_iters: 150,
                d_history: d_hist,
                wk_xi: if is_wk { xi } else { 0.1 },
                ps_xi: if is_wk { 1.0 } else { xi },
                alpha: Some(alpha),
                record_thetas: true,
                ..Default::default()
            };
            let t = run(&p, algo, &opts, &NativeEngine::new(&p));
            let vs = lyapunov_values(&p, &t.thetas, d_hist, xi, alpha);
            let floor = 1e-12 * vs[0].max(1e-300);
            for (i, w) in vs.windows(2).enumerate() {
                if w[0] < floor {
                    break;
                }
                assert!(
                    w[1] <= w[0] * (1.0 + 1e-9),
                    "{:?} k={} V increased {} -> {}",
                    algo,
                    i,
                    w[0],
                    w[1]
                );
            }
        }
    }
}

/// Lemma 4 (lazy communication): a worker whose importance satisfies
/// H²(m) ≤ γ_d = ξ_d/(d α² L² M²) uploads at most k/(d+1) times in any
/// window of k iterations (checked globally here).
#[test]
fn prop_lemma4_upload_frequency_bound() {
    let mut rng = Rng::new(505);
    for _ in 0..5 {
        let p = random_problem(&mut rng);
        let d_hist = 10;
        let xi = 0.1;
        let iters = 400;
        let opts = RunOptions {
            max_iters: iters,
            d_history: d_hist,
            wk_xi: xi,
            stop_at_target: false,
            ..Default::default()
        };
        let t = run(&p, Algorithm::LagWk, &opts, &NativeEngine::new(&p));
        let alpha = t.alpha;
        let l = p.l_total;
        let m = p.m() as f64;
        for (mi, h) in p.importance().iter().enumerate() {
            // the largest d (1..=D) for which H²(m) ≤ γ_d
            let mut best_d = 0usize;
            for dd in 1..=d_hist {
                let gamma_d = xi / (dd as f64 * alpha * alpha * l * l * m * m);
                if h * h <= gamma_d {
                    best_d = dd;
                }
            }
            if best_d == 0 {
                continue;
            }
            let bound = iters / (best_d + 1) + 1; // +1 for the forced first round
            let actual = t.upload_events[mi].len();
            assert!(
                actual <= bound,
                "worker {mi}: H={h:.4}, d={best_d}: {actual} uploads > bound {bound}"
            );
        }
    }
}

/// Monotone trigger: a larger ξ (lazier rule) never increases the number of
/// uploads per converged run... (not strictly guaranteed per-iteration, but
/// total communication at a fixed iteration budget is expected to be
/// monotone in practice; we assert the weak version: ξ=0 is an upper bound.)
#[test]
fn prop_xi_zero_is_upload_upper_bound() {
    let mut rng = Rng::new(606);
    for _ in 0..5 {
        let p = random_problem(&mut rng);
        let iters = 120;
        let base = RunOptions { max_iters: iters, stop_at_target: false, ..Default::default() };
        let zero = run(
            &p,
            Algorithm::LagWk,
            &RunOptions { wk_xi: 0.0, ..base.clone() },
            &NativeEngine::new(&p),
        );
        for xi in [0.05, 0.1, 0.5] {
            let t = run(
                &p,
                Algorithm::LagWk,
                &RunOptions { wk_xi: xi, ..base.clone() },
                &NativeEngine::new(&p),
            );
            assert!(
                t.total_uploads() <= zero.total_uploads(),
                "xi={xi}: {} > {}",
                t.total_uploads(),
                zero.total_uploads()
            );
        }
    }
}

/// Convergence: all five algorithms reach the target on well-conditioned
/// random problems (strongly-convex case, Theorems 1 & the IAG analyses).
#[test]
fn prop_all_algorithms_converge() {
    let mut rng = Rng::new(707);
    for _ in 0..3 {
        let p = random_problem(&mut rng);
        for algo in Algorithm::ALL {
            let opts = RunOptions {
                max_iters: 60_000,
                target_err: Some(1e-7),
                seed: 42,
                ..Default::default()
            };
            let t = run(&p, algo, &opts, &NativeEngine::new(&p));
            assert!(
                t.converged_iter.is_some(),
                "{} did not reach 1e-7 on {} (err={:.3e})",
                t.algo,
                p.name,
                t.final_err()
            );
        }
    }
}
