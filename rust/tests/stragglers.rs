//! Straggler soak: deadline-paced rounds over a 16-worker loopback fleet
//! with scheduled straggler windows, plus a wall-clock pacing smoke test
//! with a genuinely slow worker (DESIGN.md §13).
//!
//! What this certifies:
//!
//! 1. **Partial aggregation is LAG.** Rounds committed without a parked
//!    member are exact LAG forced skips — the cached gradient stands in,
//!    and the late reply lands stamped with the round it answered, so
//!    staleness accounting stays honest.
//! 2. **Pacing is deterministic.** Straggle decisions are keyed to the
//!    virtual round clock, so two runs of the same plan byte-compare
//!    equal (records, upload events, final iterate) however the real
//!    socket timing interleaves.
//! 3. **The staleness cap holds.** No shard's upload-event gap ever
//!    exceeds `max_staleness` — the cap force-waits a member before its
//!    age can reach D+1.
//! 4. **The fleet keeps pace.** A worker that sleeps through every round
//!    budget slows nobody down: the honest majority commits on the pace
//!    deadline and the sleeper's replies trickle in as forced skips.
//!
//! CI runs this with `cargo test --release --test stragglers`.

mod common;

use common::{drive, record_sig, sopts, theta_bits, WALL_BUDGET};
use lag::coordinator::{
    run_service, serve_worker, Algorithm, FaultPlan, FrameDecoder, RunOptions, ServiceOptions,
    WireMsg, WorkerConfig, WorkerExit,
};
use lag::data::synthetic;
use lag::grad::worker_grad;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// The headline soak: 16 workers, 2 of them straggling through three
/// scheduled windows, a staleness cap of D = 6, and deadline pacing
/// armed. Every round commits, forced-skip accounting matches the plan
/// exactly, no upload-event gap exceeds D, nobody is evicted — and two
/// independent executions byte-compare equal.
#[test]
fn sixteen_worker_straggler_soak_is_bit_deterministic() {
    const D: usize = 6;
    let m = 16;
    let p = synthetic::linreg_increasing_l(m, 8, 6, 3001);
    let opts = RunOptions { max_iters: 40, record_every: 1, ..Default::default() };
    // two straggling shards, three windows, each shorter than D so the
    // plan and the cap never fight (the cap outranks the plan)
    let windows = [(6usize, 3usize, 9usize), (12, 11, 16), (20, 3, 24)];
    let faults = FaultPlan { straggle: windows.to_vec(), ..Default::default() };
    let so = ServiceOptions {
        round_deadline: Some(Duration::from_secs(10)),
        max_staleness: D,
        ..sopts()
    };

    // GD (rhs = 0): every broadcast member uploads every round, so the
    // upload-event structure is fully determined by the pacing machinery
    let t0 = Instant::now();
    let (ta, sa) = drive(&p, Algorithm::Gd, &opts, &so, &faults);
    let (tb, sb) = drive(&p, Algorithm::Gd, &opts, &so, &faults);
    let elapsed = t0.elapsed();
    assert!(elapsed < WALL_BUDGET, "straggler soak blew the wall budget: {elapsed:?}");

    // bit-determinism across executions
    assert_eq!(record_sig(&ta.records), record_sig(&tb.records));
    assert_eq!(ta.upload_events, tb.upload_events);
    assert_eq!(theta_bits(&sa.final_theta), theta_bits(&sb.final_theta));

    // every round committed, with the whole fleet intact at the end
    assert_eq!(ta.records.last().unwrap().k, opts.max_iters);
    assert_eq!(sa.evictions, 0);
    assert_eq!(sa.quarantined, 0);
    assert_eq!(sa.joins, m as u64);

    // forced skips are exactly the plan's window lengths: each (fk, s,
    // rk) carries the shard through commits fk..rk on its cached gradient
    let expected: usize = windows.iter().map(|&(fk, _, rk)| rk - fk).sum();
    assert_eq!(sa.forced_skips, expected as u64);
    assert_eq!(sb.forced_skips, expected as u64);

    // the parked reply is stamped with the round it answered (the window
    // start), and the shard is dark through the window interior
    for &(fk, s, rk) in &windows {
        assert!(ta.upload_events[s].contains(&fk), "shard {s}: no upload stamped {fk}");
        assert!(
            ta.upload_events[s].iter().all(|&k| !(fk + 1..=rk).contains(&k)),
            "shard {s} uploaded inside its straggle window"
        );
    }

    // staleness discipline: under GD every broadcast produces an upload,
    // so consecutive upload-event gaps bound each shard's committed age —
    // none may exceed the cap
    for s in 0..m {
        for w in ta.upload_events[s].windows(2) {
            assert!(
                w[1] - w[0] <= D,
                "shard {s}: upload gap {} -> {} exceeds the D = {D} staleness cap",
                w[0],
                w[1]
            );
        }
    }
}

/// Wall-clock pacing smoke test: a worker that sleeps well past the pace
/// deadline on every round must not slow the fleet — the honest majority
/// commits on the deadline, the sleeper's late replies land as parked
/// uploads, and nobody is evicted.
#[test]
fn slow_worker_does_not_slow_the_fleet() {
    let m = 3;
    let sleeper = 2usize;
    let nap = Duration::from_millis(300);
    let p = synthetic::linreg_increasing_l(m, 8, 5, 3002);
    let opts = RunOptions { max_iters: 30, record_every: 1, ..Default::default() };
    let so = ServiceOptions {
        round_deadline: Some(Duration::from_millis(50)),
        heartbeat_timeout: Duration::from_secs(30),
        ..sopts()
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let p = &p;
    let t0 = Instant::now();
    let (trace, stats) = std::thread::scope(|scope| {
        let leader = scope.spawn(|| {
            run_service(listener, p, Algorithm::Gd, &opts, &so, &FaultPlan::default()).unwrap()
        });
        for s in 0..m - 1 {
            let addr = addr.clone();
            scope.spawn(move || {
                let cfg = WorkerConfig {
                    preferred: Some(s),
                    heartbeat_interval: Duration::from_millis(20),
                    leader_timeout: Duration::from_secs(90),
                    ..Default::default()
                };
                loop {
                    match serve_worker(&addr, p, &cfg) {
                        Ok(o) if o.exit == WorkerExit::Shutdown => break,
                        Ok(_) => std::thread::sleep(Duration::from_millis(2)),
                        Err(_) => break,
                    }
                }
            });
        }
        // the sleeper speaks the protocol honestly but naps through every
        // round budget before computing its gradient
        scope.spawn({
            let addr = addr.clone();
            move || {
                let mut stream = TcpStream::connect(&addr).unwrap();
                stream.write_all(&WireMsg::Hello { worker: sleeper as u32 }.encode()).unwrap();
                let mut dec = FrameDecoder::new();
                let mut cache: Option<Vec<f64>> = None;
                let mut buf = [0u8; 65536];
                'session: loop {
                    let n = match stream.read(&mut buf) {
                        Ok(0) | Err(_) => break 'session,
                        Ok(n) => n,
                    };
                    let mut msgs = Vec::new();
                    if dec.feed(&buf[..n], &mut msgs).is_err() {
                        break 'session;
                    }
                    for msg in msgs {
                        match msg {
                            WireMsg::Assign { cached, .. } => cache = cached,
                            WireMsg::Round { k, theta, .. } => {
                                std::thread::sleep(nap);
                                let (g, _) = worker_grad(p.task, &p.workers[sleeper], &theta);
                                let delta: Vec<f64> = match &cache {
                                    Some(c) => g.iter().zip(c).map(|(a, b)| a - b).collect(),
                                    None => g.clone(),
                                };
                                cache = Some(g);
                                let frame = WireMsg::Delta {
                                    k,
                                    worker: sleeper as u32,
                                    delta: Some(delta),
                                }
                                .encode();
                                if stream.write_all(&frame).is_err() {
                                    break 'session;
                                }
                            }
                            WireMsg::Shutdown => break 'session,
                            _ => {}
                        }
                    }
                }
            }
        });
        leader.join().unwrap()
    });
    let elapsed = t0.elapsed();

    // every round committed, and the run took nowhere near 30 naps —
    // the sleeper was paced around, not waited for
    assert_eq!(trace.records.last().unwrap().k, opts.max_iters);
    assert!(
        elapsed < nap * 10,
        "fleet did not keep pace: {elapsed:?} for 30 rounds around a {nap:?} sleeper"
    );
    assert!(elapsed < WALL_BUDGET);

    // the sleeper was carried as forced skips, never evicted
    assert!(stats.forced_skips >= 2, "only {} forced skips", stats.forced_skips);
    assert_eq!(stats.evictions, 0);
    assert_eq!(stats.quarantined, 0);

    // its parked uploads landed honestly: stamped with the rounds they
    // answered, strictly increasing
    let ev = &trace.upload_events[sleeper];
    assert!(!ev.is_empty(), "sleeper never uploaded");
    assert!(ev.windows(2).all(|w| w[0] < w[1]));
    // and the honest majority uploaded nearly every round under GD
    for s in 0..m - 1 {
        assert!(
            trace.upload_events[s].len() >= opts.max_iters - 2,
            "honest shard {s} uploaded only {} times",
            trace.upload_events[s].len()
        );
    }
}
