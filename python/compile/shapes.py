"""Artifact shape registry.

Every AOT artifact is identified by (kind, n, d) or a named transformer
config.  The Rust runtime loads ``artifacts/manifest.json`` (written by
``aot.py``) and resolves executables by these names, so this file is the
single source of truth shared by the compile path and the tests.

Per-worker shards are zero-padded (weight ``w = 0``) up to the registered
``n`` so a single compiled executable serves every worker of an experiment.
"""

from __future__ import annotations

from dataclasses import dataclass, field


def pick_block(n: int, target: int = 64) -> int:
    """Largest divisor of ``n`` that is <= ``target``.

    Pallas grids here require the row-block to divide ``n`` exactly; the
    registered shapes are chosen so a reasonable divisor always exists.
    """
    best = 1
    for b in range(1, min(n, target) + 1):
        if n % b == 0:
            best = b
    return best


# ---------------------------------------------------------------------------
# Regression artifacts (f64: the paper's MATLAB experiments are double
# precision and Table 5 targets an absolute objective error of 1e-8, which is
# below f32 resolution at these loss magnitudes).
# ---------------------------------------------------------------------------

#: (n, d) per worker: synthetic experiments (Figs. 2-4) use 50 samples of
#: dimension 50 per worker; the "real data" experiments (Figs. 5-6, Table 5)
#: pad each shard to a common shape per task.
LINREG_SHAPES: list[tuple[int, int]] = [
    (50, 50),   # synthetic, Figs. 2-3
    (176, 8),   # Housing/Bodyfat/Abalone shards (max shard 169 @ M=9)
]

LOGREG_SHAPES: list[tuple[int, int]] = [
    (50, 50),    # synthetic, Fig. 4
    (544, 34),   # Ionosphere/Adult/Derm shards (max shard 535 @ M=9)
    (224, 4837), # Gisette, Fig. 7 (2000 samples over 9 workers)
]

#: ℓ2 regularization for logistic regression (paper §4).
LOGREG_LAMBDA = 1e-3


# ---------------------------------------------------------------------------
# Transformer configs (f32) for the end-to-end LAG training driver.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TransformerConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        d, f = self.d_model, self.d_ff
        per_layer = 2 * d + 4 * d * d + 2 * d + d * f + f + f * d + d
        return self.vocab * d + self.seq_len * d + self.n_layers * per_layer + 2 * d


TRANSFORMER_CONFIGS: dict[str, TransformerConfig] = {
    # Small enough for unit tests and the pytest suite.
    "tiny": TransformerConfig(
        name="tiny", vocab=64, d_model=32, n_layers=2, n_heads=2,
        d_ff=64, seq_len=16, batch=4,
    ),
    # The end-to-end driver: ~1.3M params, a few hundred LAG steps on CPU.
    "e2e": TransformerConfig(
        name="e2e", vocab=512, d_model=128, n_layers=4, n_heads=4,
        d_ff=512, seq_len=64, batch=8,
    ),
    # Paper-scale config (~110M params). Registered so the config system is
    # complete; AOT-compiled only when LAG_AOT_100M=1 (hours on CPU).
    "gpt100m": TransformerConfig(
        name="gpt100m", vocab=32768, d_model=768, n_layers=12, n_heads=12,
        d_ff=3072, seq_len=256, batch=8,
    ),
}


def linreg_name(n: int, d: int) -> str:
    return f"linreg_grad_{n}x{d}"


def logreg_name(n: int, d: int) -> str:
    return f"logreg_grad_{n}x{d}"


def transformer_name(cfg: TransformerConfig) -> str:
    return f"transformer_step_{cfg.name}"
