"""AOT pipeline: lower every (task, shape) to HLO text + manifest.

Run once at build time (``make artifacts``); the Rust runtime then loads
``artifacts/manifest.json`` and compiles each ``*.hlo.txt`` on the PJRT CPU
client.  Interchange is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly.

Incremental: a content hash of the compile package is stored in the
manifest; unchanged inputs make this a no-op.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

# f64 artifacts: the paper's experiments target objective error 1e-8, below
# f32 resolution at the loss magnitudes involved.
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model, shapes, transformer  # noqa: E402

F64 = jnp.float64
F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sources_digest() -> str:
    """Hash of every .py file under compile/ — the incremental-build key."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _dirs, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(f.encode())
                    h.update(fh.read())
    return h.hexdigest()


def _lower_regression(kind: str, n: int, d: int):
    spec_x = jax.ShapeDtypeStruct((n, d), F64)
    spec_v = jax.ShapeDtypeStruct((n,), F64)
    spec_t = jax.ShapeDtypeStruct((d,), F64)
    fn = model.linreg_worker if kind == "linreg" else model.logreg_worker
    return jax.jit(fn).lower(spec_x, spec_v, spec_v, spec_t)


def _lower_transformer(cfg: shapes.TransformerConfig):
    specs = [jax.ShapeDtypeStruct(tuple(s["shape"]), F32)
             for s in transformer.param_specs(cfg)]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    fn = lambda params, tokens: transformer.loss_and_grads(params, tokens, cfg)  # noqa: E731
    return jax.jit(fn).lower(specs, tok)


def build(out_dir: str, *, force: bool = False, include_100m: bool = False,
          verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    digest = _sources_digest()

    if not force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("digest") == digest and all(
                os.path.exists(os.path.join(out_dir, e["file"]))
                for e in old.get("entries", [])
            ):
                if verbose:
                    print(f"artifacts up to date ({len(old['entries'])} entries)")
                return old
        except (json.JSONDecodeError, KeyError):
            pass

    entries = []

    def emit(name: str, lowered, extra: dict):
        t0 = time.time()
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({"name": name, "file": fname, **extra})
        if verbose:
            print(f"  {name}: {len(text)} chars in {time.time() - t0:.1f}s")

    if verbose:
        print("lowering regression artifacts (f64)...")
    for (n, d) in shapes.LINREG_SHAPES:
        emit(shapes.linreg_name(n, d), _lower_regression("linreg", n, d),
             {"kind": "linreg", "n": n, "d": d, "dtype": "f64",
              "outputs": ["grad", "loss"]})
    for (n, d) in shapes.LOGREG_SHAPES:
        emit(shapes.logreg_name(n, d), _lower_regression("logreg", n, d),
             {"kind": "logreg", "n": n, "d": d, "dtype": "f64",
              "lam": shapes.LOGREG_LAMBDA, "outputs": ["grad", "loss"]})

    if verbose:
        print("lowering transformer artifacts (f32)...")
    for cname, cfg in shapes.TRANSFORMER_CONFIGS.items():
        if cname == "gpt100m" and not include_100m:
            continue
        emit(shapes.transformer_name(cfg), _lower_transformer(cfg),
             {"kind": "transformer", "dtype": "f32",
              "config": {"vocab": cfg.vocab, "d_model": cfg.d_model,
                         "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
                         "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
                         "batch": cfg.batch,
                         "n_params": cfg.n_params()},
              "params": transformer.param_specs(cfg),
              "outputs": ["loss", "grads..."]})

    manifest = {"version": 1, "digest": digest, "entries": entries}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {manifest_path} ({len(entries)} entries)")
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--include-100m", action="store_true",
                    default=os.environ.get("LAG_AOT_100M") == "1")
    args = ap.parse_args()
    build(args.out, force=args.force, include_100m=args.include_100m)
    return 0


if __name__ == "__main__":
    sys.exit(main())
