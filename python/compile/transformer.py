"""L2: decoder-only transformer LM whose MLP matmuls route through the
Pallas blocked-matmul kernel (L1).

Used by the end-to-end driver: Rust runs LAG across workers whose local
gradients are this model's full-batch grads, computed by the AOT artifact
``transformer_step_<cfg>``.

Parameters travel as a *flat ordered list* of arrays; the ordering and the
init scheme are recorded in the manifest so the Rust side can materialize
initial parameters without Python.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.matmul import pmatmul
from .shapes import TransformerConfig

F32 = jnp.float32


def param_specs(cfg: TransformerConfig) -> list[dict]:
    """Ordered parameter manifest: name, shape, init ('normal'/'zeros'/'ones'), std."""
    d, f = cfg.d_model, cfg.d_ff
    std = 0.02
    specs: list[dict] = [
        {"name": "tok_emb", "shape": [cfg.vocab, d], "init": "normal", "std": std},
        {"name": "pos_emb", "shape": [cfg.seq_len, d], "init": "normal", "std": std},
    ]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        specs += [
            {"name": p + "ln1_scale", "shape": [d], "init": "ones", "std": 0.0},
            {"name": p + "ln1_bias", "shape": [d], "init": "zeros", "std": 0.0},
            {"name": p + "wq", "shape": [d, d], "init": "normal", "std": std},
            {"name": p + "wk", "shape": [d, d], "init": "normal", "std": std},
            {"name": p + "wv", "shape": [d, d], "init": "normal", "std": std},
            {"name": p + "wo", "shape": [d, d], "init": "normal", "std": std},
            {"name": p + "ln2_scale", "shape": [d], "init": "ones", "std": 0.0},
            {"name": p + "ln2_bias", "shape": [d], "init": "zeros", "std": 0.0},
            {"name": p + "w1", "shape": [d, f], "init": "normal", "std": std},
            {"name": p + "b1", "shape": [f], "init": "zeros", "std": 0.0},
            {"name": p + "w2", "shape": [f, d], "init": "normal", "std": std},
            {"name": p + "b2", "shape": [d], "init": "zeros", "std": 0.0},
        ]
    specs += [
        {"name": "lnf_scale", "shape": [d], "init": "ones", "std": 0.0},
        {"name": "lnf_bias", "shape": [d], "init": "zeros", "std": 0.0},
    ]
    return specs


def init_params(cfg: TransformerConfig, seed: int = 0) -> list[jnp.ndarray]:
    """Reference initializer (tests only; Rust re-derives from the manifest)."""
    rng = np.random.default_rng(seed)
    out = []
    for s in param_specs(cfg):
        if s["init"] == "normal":
            out.append(jnp.asarray(rng.normal(0.0, s["std"], s["shape"]), F32))
        elif s["init"] == "ones":
            out.append(jnp.ones(s["shape"], F32))
        else:
            out.append(jnp.zeros(s["shape"], F32))
    return out


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _mlp_matmul(x2d, w):
    """Route through the Pallas kernel when block shapes divide; jnp fallback
    keeps the tiny test config valid for arbitrary sizes."""
    m, k = x2d.shape
    n = w.shape[1]
    if m % 16 == 0 and k % 16 == 0 and n % 16 == 0:
        return pmatmul(x2d, w)
    return x2d @ w


def forward_loss(params: list, tokens: jnp.ndarray, cfg: TransformerConfig):
    """Next-token cross-entropy over a [B, T] int32 batch. Tied output head."""
    it = iter(params)
    tok_emb = next(it)
    pos_emb = next(it)
    b, t = tokens.shape
    h = tok_emb[tokens] + pos_emb[None, :t, :]

    mask = jnp.tril(jnp.ones((t, t), F32))
    neg = jnp.asarray(-1e9, F32)

    for _ in range(cfg.n_layers):
        ln1_s, ln1_b = next(it), next(it)
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        ln2_s, ln2_b = next(it), next(it)
        w1, b1, w2, b2 = next(it), next(it), next(it), next(it)

        x = _layernorm(h, ln1_s, ln1_b)
        q = (x @ wq).reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = (x @ wk).reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = (x @ wv).reshape(b, t, cfg.n_heads, cfg.head_dim)
        att = jnp.einsum("bihd,bjhd->bhij", q, k) / jnp.sqrt(
            jnp.asarray(cfg.head_dim, F32))
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhij,bjhd->bihd", att, v).reshape(b, t, cfg.d_model)
        h = h + o @ wo

        x = _layernorm(h, ln2_s, ln2_b)
        x2 = x.reshape(b * t, cfg.d_model)
        hmid = jax.nn.gelu(_mlp_matmul(x2, w1) + b1)
        out = _mlp_matmul(hmid, w2) + b2
        h = h + out.reshape(b, t, cfg.d_model)

    lnf_s, lnf_b = next(it), next(it)
    h = _layernorm(h, lnf_s, lnf_b)
    logits = h @ tok_emb.T  # tied head, [B, T, V]

    # next-token prediction: positions 0..T-2 predict tokens 1..T-1
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def loss_and_grads(params: list, tokens: jnp.ndarray, cfg: TransformerConfig):
    """(loss, grads...) — the AOT'd per-worker LAG computation."""
    loss, grads = jax.value_and_grad(
        lambda ps: forward_loss(ps, tokens, cfg))(params)
    return (loss, *grads)
