"""L2: per-worker LAG computations, wired to the L1 Pallas kernels.

Each function here is the computation a worker executes once per contacted
round: full-batch gradient + loss over its (padded) shard.  ``aot.py``
lowers these, at the shapes in ``shapes.py``, to the HLO-text artifacts the
Rust runtime loads.

Python never runs on the training path: these exist only to be lowered.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import kernels
from .shapes import LOGREG_LAMBDA


def linreg_worker(x, y, w, theta):
    """Weighted least-squares (grad, loss) for one worker shard.

    Padding rows carry w=0 and contribute exactly nothing, so one compiled
    executable serves every worker of an experiment.
    """
    grad, loss = kernels.linreg_grad(x, y, w, theta)
    return grad, loss[0]


def logreg_worker(x, y, w, theta, lam: float = LOGREG_LAMBDA):
    """l2-regularized logistic (grad, loss) for one worker shard (y in +-1)."""
    grad, loss = kernels.logreg_grad(x, y, w, theta, lam=lam)
    return grad, loss[0]


def linreg_worker_ref(x, y, w, theta):
    """Pure-jnp path (oracle); used by tests and HLO-level cross-checks."""
    from .kernels import ref
    return ref.linreg_grad_ref(x, y, w, theta)


def logreg_worker_ref(x, y, w, theta, lam: float = LOGREG_LAMBDA):
    from .kernels import ref
    return ref.logreg_grad_ref(x, y, w, theta, lam)
