"""L1 Pallas kernels (build-time only; lowered into the AOT artifacts)."""

from .linreg_grad import linreg_grad
from .logreg_grad import logreg_grad
from .matmul import pmatmul

__all__ = ["linreg_grad", "logreg_grad", "pmatmul"]
