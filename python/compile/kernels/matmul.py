"""Blocked Pallas matmul with a custom VJP, used by the transformer MLP.

Forward and both backward products route through the same kernel, so the
Pallas hot-spot sits inside the lowered fwd+bwd HLO that the Rust runtime
executes.  Grid is (M/bm, N/bn, K/bk) with the K axis innermost and the
output block revisited across the K loop (accumulate-in-VMEM schedule; on a
real TPU this targets the MXU with one [bm,bk]x[bk,bn] systolic pass per
step).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


def _largest_divisor(n: int, target: int) -> int:
    best = 1
    for b in range(1, min(n, target) + 1):
        if n % b == 0:
            best = b
    return best


# Default block target: 256 keeps the three VMEM panels (A, B, accumulator)
# under ~1 MB f32 — comfortably inside a TPU core's ~16 MB VMEM — while
# minimizing grid steps (the dominant cost in interpret mode too: the §Perf
# sweep measured 64→256 as a 4.3x step-time reduction on the e2e model).
_BLOCK_TARGET = 256


def _pallas_matmul(a, b, *, bm: int | None = None, bn: int | None = None,
                   bk: int | None = None):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = bm or _largest_divisor(m, _BLOCK_TARGET)
    bn = bn or _largest_divisor(n, _BLOCK_TARGET)
    bk = bk or _largest_divisor(k, _BLOCK_TARGET)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def pmatmul(a, b):
    """``a @ b`` through the blocked Pallas kernel, differentiable."""
    return _pallas_matmul(a, b)


def _fwd(a, b):
    return _pallas_matmul(a, b), (a, b)


def _bwd(res, g):
    a, b = res
    # dA = g @ B^T, dB = A^T @ g — both through the same Pallas kernel.
    da = _pallas_matmul(g, b.T)
    db = _pallas_matmul(a.T, g)
    return da, db


pmatmul.defvjp(_fwd, _bwd)


def vmem_estimate(m: int, n: int, k: int, bm: int = 64, bn: int = 64,
                  bk: int = 64, bytes_per_el: int = 4) -> int:
    """VMEM bytes per grid step (A panel + B panel + output accumulator)."""
    return bytes_per_el * (bm * bk + bk * bn + bm * bn)
