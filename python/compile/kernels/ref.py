"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its oracle to float tolerance across the shape/dtype sweep in
``python/tests``.  They are also what the L2 model *would* be without the
Pallas hot-spot, so the AOT tests additionally check kernel-vs-oracle at the
lowered-HLO level.
"""

from __future__ import annotations

import jax.numpy as jnp


def linreg_grad_ref(x, y, w, theta):
    """Weighted least-squares gradient and loss (paper eq. (85)).

    loss = sum_i w_i (x_i.theta - y_i)^2, grad = 2 X^T (w * (X theta - y)).
    ``w`` doubles as the shard-padding mask (0 rows contribute nothing).
    """
    res = x @ theta - y
    r = w * res
    grad = 2.0 * (x.T @ r)
    loss = jnp.dot(r, res)
    return grad, loss


def logreg_grad_ref(x, y, w, theta, lam):
    """l2-regularized logistic gradient and loss (paper eq. (86)).

    loss = sum_i w_i log(1 + exp(-y_i x_i.theta)) + lam/2 ||theta||^2
    grad = X^T (w * (-y * sigmoid(-y X theta))) + lam * theta
    Labels y are +-1.
    """
    z = x @ theta
    u = -y * z
    s = jnp.where(u >= 0, 1.0 / (1.0 + jnp.exp(-jnp.abs(u))),
                  jnp.exp(-jnp.abs(u)) / (1.0 + jnp.exp(-jnp.abs(u))))
    grad = x.T @ (w * (-y) * s) + lam * theta
    loss = jnp.sum(w * jnp.logaddexp(0.0, u)) + 0.5 * lam * jnp.dot(theta, theta)
    return grad, loss


def matmul_ref(a, b):
    """Plain matmul oracle for the blocked Pallas kernel."""
    return a @ b
