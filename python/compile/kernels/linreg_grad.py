"""Pallas kernel: weighted least-squares gradient + loss.

The per-worker hot spot of LAG for linear regression (paper eq. (85)):

    loss = sum_i w_i (x_i.theta - y_i)^2
    grad = 2 X^T (w ⊙ (X theta - y))

TPU mapping (see DESIGN.md §8): X is streamed HBM→VMEM in row panels of
``block_n`` rows; the residual is produced per panel and the rank-``block_n``
update ``2 * r @ X_panel`` accumulates into a VMEM-resident [d] output block
(same output block revisited every grid step — the canonical Pallas
reduction schedule).  The two panel products are MXU-shaped matmuls.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import pick_block


def _kernel(x_ref, y_ref, w_ref, th_ref, g_ref, l_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        l_ref[...] = jnp.zeros_like(l_ref)

    xb = x_ref[...]                       # [bn, d] panel in VMEM
    res = xb @ th_ref[...] - y_ref[...]   # [bn]
    r = w_ref[...] * res                  # weighted residual
    g_ref[...] += 2.0 * (r @ xb)          # rank-bn update of the [d] grad
    l_ref[...] += jnp.sum(r * res)[None]  # scalar loss accumulator


def linreg_grad(x, y, w, theta, *, block_n: int | None = None):
    """Compute (grad, loss) with the Pallas pipeline. Shapes: x [n,d], y/w [n], theta [d]."""
    n, d = x.shape
    bn = block_n or pick_block(n)
    if n % bn != 0:
        raise ValueError(f"block_n={bn} must divide n={n}")
    dt = x.dtype
    grid = (n // bn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), dt),
            jax.ShapeDtypeStruct((1,), dt),
        ],
        interpret=True,
    )(x, y, w, theta)


@functools.lru_cache(maxsize=None)
def vmem_estimate(n: int, d: int, block_n: int | None = None, bytes_per_el: int = 8) -> int:
    """Estimated VMEM footprint (bytes) of one grid step — recorded in §Perf."""
    bn = block_n or pick_block(n)
    # X panel + y + w blocks + theta + grad accumulator + loss
    return bytes_per_el * (bn * d + bn + bn + d + d + 1)
