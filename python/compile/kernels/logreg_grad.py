"""Pallas kernel: ℓ2-regularized logistic-regression gradient + loss.

Per-worker hot spot of LAG for classification (paper eq. (86)):

    loss = sum_i w_i log(1 + exp(-y_i x_i.theta)) + lam/2 ||theta||^2
    grad = X^T (w ⊙ (-y ⊙ σ(-y ⊙ X theta))) + lam theta

Same row-panel schedule as ``linreg_grad``: the sigmoid residual is fused
with the panel matvec so X is read exactly once, and the regularizer is
applied on the final grid step (``pl.when(i == num_programs-1)``) so the
accumulator never needs a second pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..shapes import pick_block


def _make_kernel(lam: float):
    def kernel(x_ref, y_ref, w_ref, th_ref, g_ref, l_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            g_ref[...] = jnp.zeros_like(g_ref)
            l_ref[...] = jnp.zeros_like(l_ref)

        xb = x_ref[...]                    # [bn, d]
        yb = y_ref[...]
        wb = w_ref[...]
        th = th_ref[...]
        z = xb @ th                        # [bn] margins
        u = -yb * z
        # numerically stable sigmoid(u): exp(-|u|) never overflows, so both
        # branches of the select are finite (select evaluates both).
        e = jnp.exp(-jnp.abs(u))
        s = jnp.where(u >= 0, 1.0 / (1.0 + e), e / (1.0 + e))
        r = wb * (-yb) * s                 # residual, fused with the mask
        g_ref[...] += r @ xb
        l_ref[...] += jnp.sum(wb * jnp.logaddexp(0.0, u))[None]

        @pl.when(i == pl.num_programs(0) - 1)
        def _reg():
            g_ref[...] += lam * th
            l_ref[...] += (0.5 * lam * jnp.sum(th * th))[None]

    return kernel


def logreg_grad(x, y, w, theta, *, lam: float, block_n: int | None = None):
    """Compute (grad, loss). Shapes: x [n,d], y/w [n] (y in {-1,+1}), theta [d]."""
    n, d = x.shape
    bn = block_n or pick_block(n)
    if n % bn != 0:
        raise ValueError(f"block_n={bn} must divide n={n}")
    dt = x.dtype
    return pl.pallas_call(
        _make_kernel(float(lam)),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), dt),
            jax.ShapeDtypeStruct((1,), dt),
        ],
        interpret=True,
    )(x, y, w, theta)


@functools.lru_cache(maxsize=None)
def vmem_estimate(n: int, d: int, block_n: int | None = None, bytes_per_el: int = 8) -> int:
    bn = block_n or pick_block(n)
    return bytes_per_el * (bn * d + 3 * bn + d + d + 1)
