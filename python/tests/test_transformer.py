"""L2 transformer: shapes, init-loss sanity, grads, trainability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import transformer
from compile.shapes import TRANSFORMER_CONFIGS

CFG = TRANSFORMER_CONFIGS["tiny"]


def _tokens(seed, cfg=CFG):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)),
                       jnp.int32)


def test_param_specs_count_matches_config():
    specs = transformer.param_specs(CFG)
    total = sum(int(np.prod(s["shape"])) for s in specs)
    assert total == CFG.n_params()


def test_param_specs_ordering_stable():
    """The manifest ordering contract with the Rust side."""
    names = [s["name"] for s in transformer.param_specs(CFG)]
    assert names[0] == "tok_emb" and names[1] == "pos_emb"
    assert names[-2:] == ["lnf_scale", "lnf_bias"]
    assert names[2] == "layer0.ln1_scale"
    assert len(names) == len(set(names))


def test_init_loss_near_log_vocab():
    params = transformer.init_params(CFG, 0)
    loss = transformer.forward_loss(params, _tokens(0), CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_loss_and_grads_shapes():
    params = transformer.init_params(CFG, 0)
    out = transformer.loss_and_grads(params, _tokens(0), CFG)
    assert len(out) == 1 + len(params)
    assert jnp.shape(out[0]) == ()
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert g.dtype == p.dtype


def test_grads_nonzero_and_finite():
    params = transformer.init_params(CFG, 1)
    out = transformer.loss_and_grads(params, _tokens(1), CFG)
    norms = [float(jnp.linalg.norm(g)) for g in out[1:]]
    assert all(np.isfinite(n) for n in norms)
    # everything except maybe biases should receive signal
    assert sum(n > 0 for n in norms) >= len(norms) - 2


def test_deterministic():
    params = transformer.init_params(CFG, 2)
    tok = _tokens(2)
    a = transformer.loss_and_grads(params, tok, CFG)
    b = transformer.loss_and_grads(params, tok, CFG)
    assert float(a[0]) == float(b[0])
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_few_gd_steps_reduce_loss():
    params = transformer.init_params(CFG, 3)
    tok = _tokens(3)
    step = jax.jit(lambda ps: transformer.loss_and_grads(ps, tok, CFG))
    out = step(params)
    first = float(out[0])
    lr = 0.5
    for _ in range(10):
        out = step(params)
        params = [p - lr * g for p, g in zip(params, out[1:])]
    last = float(step(params)[0])
    assert last < first - 0.05, (first, last)


def test_causality():
    """Changing a future token must not change earlier positions' loss terms."""
    cfg = CFG
    params = transformer.init_params(cfg, 4)
    tok = np.asarray(_tokens(4))

    def per_pos_nll(tokens):
        it = jnp.asarray(tokens, jnp.int32)
        # replicate forward_loss but return per-position nll
        loss_full = transformer.forward_loss(params, it, cfg)
        return loss_full

    tok2 = tok.copy()
    tok2[:, -1] = (tok2[:, -1] + 1) % cfg.vocab

    # the only positions allowed to differ in logits are those attending to
    # the changed (last) token; total loss changes, but the prefix loss
    # computed on the truncated sequence must be identical.
    prefix1 = transformer.forward_loss(params, jnp.asarray(tok[:, :-1]), cfg)
    prefix2 = transformer.forward_loss(params, jnp.asarray(tok2[:, :-1]), cfg)
    np.testing.assert_allclose(float(prefix1), float(prefix2), rtol=0)
