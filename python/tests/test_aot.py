"""AOT pipeline: manifest round-trip, HLO text validity, incrementality."""

import json
import os

import pytest

from compile import aot, shapes


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), force=True, verbose=False)
    return str(out), manifest


def test_manifest_entries_complete(built):
    out, manifest = built
    names = {e["name"] for e in manifest["entries"]}
    for (n, d) in shapes.LINREG_SHAPES:
        assert shapes.linreg_name(n, d) in names
    for (n, d) in shapes.LOGREG_SHAPES:
        assert shapes.logreg_name(n, d) in names
    assert "transformer_step_tiny" in names
    assert "transformer_step_e2e" in names
    # 100M config is registered but not AOT'd by default
    assert "transformer_step_gpt100m" not in names


def test_hlo_files_exist_and_parse_shape(built):
    out, manifest = built
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        if e["kind"] in ("linreg", "logreg"):
            # f64 artifacts with the registered shapes in the signature
            assert f"f64[{e['n']},{e['d']}]" in text
            assert e["dtype"] == "f64"


def test_manifest_json_loads(built):
    out, _ = built
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == 1
    assert m["digest"]


def test_incremental_noop(built):
    out, _ = built
    before = {f: os.path.getmtime(os.path.join(out, f)) for f in os.listdir(out)}
    aot.build(out, force=False, verbose=False)
    after = {f: os.path.getmtime(os.path.join(out, f)) for f in os.listdir(out)}
    assert before == after


def test_transformer_entry_has_param_manifest(built):
    _, manifest = built
    e = next(x for x in manifest["entries"] if x["name"] == "transformer_step_e2e")
    assert e["config"]["n_params"] == shapes.TRANSFORMER_CONFIGS["e2e"].n_params()
    specs = e["params"]
    assert specs[0]["name"] == "tok_emb"
    for s in specs:
        assert s["init"] in ("normal", "zeros", "ones")
        assert all(isinstance(v, int) for v in s["shape"])


def test_logreg_entries_record_lambda(built):
    _, manifest = built
    for e in manifest["entries"]:
        if e["kind"] == "logreg":
            assert e["lam"] == shapes.LOGREG_LAMBDA
