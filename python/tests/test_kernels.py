"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes, block sizes, and degenerate inputs; these
are the core correctness signal for everything the Rust runtime executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import kernels
from compile.kernels import ref
from compile.kernels.matmul import _pallas_matmul
from compile.shapes import pick_block

hypothesis.settings.register_profile(
    "lag", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("lag")


def _tol(dt):
    return dict(rtol=1e-10, atol=1e-10) if dt == jnp.float64 else dict(rtol=2e-4, atol=2e-4)


def _data(rng, n, d, dt):
    x = jnp.asarray(rng.normal(size=(n, d)), dt)
    y = jnp.asarray(rng.normal(size=n), dt)
    w = jnp.asarray((rng.random(n) > 0.25).astype(np.float64), dt)
    th = jnp.asarray(rng.normal(size=d), dt)
    return x, y, w, th


# ---------------------------------------------------------------------------
# linreg_grad
# ---------------------------------------------------------------------------

@given(n=st.sampled_from([8, 20, 50, 64, 176]),
       d=st.integers(1, 40),
       dt64=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
def test_linreg_matches_ref(n, d, dt64, seed):
    dt = jnp.float64 if dt64 else jnp.float32
    rng = np.random.default_rng(seed)
    x, y, w, th = _data(rng, n, d, dt)
    g, l = kernels.linreg_grad(x, y, w, th)
    gr, lr = ref.linreg_grad_ref(x, y, w, th)
    np.testing.assert_allclose(g, gr, **_tol(dt))
    np.testing.assert_allclose(l[0], lr, **_tol(dt))


@given(bn=st.sampled_from([1, 2, 5, 10, 25, 50]), seed=st.integers(0, 1000))
def test_linreg_block_size_invariant(bn, seed):
    """Result is independent of the HBM->VMEM row-panel schedule."""
    rng = np.random.default_rng(seed)
    x, y, w, th = _data(rng, 50, 13, jnp.float64)
    g, l = kernels.linreg_grad(x, y, w, th, block_n=bn)
    gr, lr = ref.linreg_grad_ref(x, y, w, th)
    np.testing.assert_allclose(g, gr, rtol=1e-10)
    np.testing.assert_allclose(l[0], lr, rtol=1e-10)


def test_linreg_all_padded_rows_zero():
    """w = 0 everywhere (fully padded shard) gives exactly zero grad/loss."""
    rng = np.random.default_rng(0)
    x, y, _w, th = _data(rng, 50, 7, jnp.float64)
    w = jnp.zeros(50, jnp.float64)
    g, l = kernels.linreg_grad(x, y, w, th)
    assert float(jnp.max(jnp.abs(g))) == 0.0
    assert float(l[0]) == 0.0


def test_linreg_padding_invariance():
    """Zero-weight padding rows change nothing — the property that lets one
    artifact serve all workers."""
    rng = np.random.default_rng(3)
    x, y, w, th = _data(rng, 40, 9, jnp.float64)
    w = jnp.ones(40, jnp.float64)
    g0, l0 = kernels.linreg_grad(x, y, w, th, block_n=8)
    xp = jnp.concatenate([x, jnp.asarray(rng.normal(size=(24, 9)))])
    yp = jnp.concatenate([y, jnp.asarray(rng.normal(size=24))])
    wp = jnp.concatenate([w, jnp.zeros(24)])
    g1, l1 = kernels.linreg_grad(xp, yp, wp, th, block_n=8)
    np.testing.assert_allclose(g0, g1, rtol=1e-12)
    np.testing.assert_allclose(l0, l1, rtol=1e-12)


def test_linreg_grad_is_autodiff_grad():
    """The analytic kernel gradient equals jax.grad of the weighted loss."""
    rng = np.random.default_rng(5)
    x, y, w, th = _data(rng, 30, 6, jnp.float64)
    loss_fn = lambda t: jnp.sum(w * (x @ t - y) ** 2)  # noqa: E731
    g_auto = jax.grad(loss_fn)(th)
    g, l = kernels.linreg_grad(x, y, w, th, block_n=10)
    np.testing.assert_allclose(g, g_auto, rtol=1e-10)
    np.testing.assert_allclose(l[0], loss_fn(th), rtol=1e-10)


def test_linreg_rejects_bad_block():
    rng = np.random.default_rng(0)
    x, y, w, th = _data(rng, 50, 3, jnp.float64)
    with pytest.raises(ValueError):
        kernels.linreg_grad(x, y, w, th, block_n=7)


# ---------------------------------------------------------------------------
# logreg_grad
# ---------------------------------------------------------------------------

@given(n=st.sampled_from([8, 20, 50, 64, 224]),
       d=st.integers(1, 40),
       lam=st.sampled_from([0.0, 1e-3, 0.1]),
       seed=st.integers(0, 2**31 - 1))
def test_logreg_matches_ref(n, d, lam, seed):
    rng = np.random.default_rng(seed)
    x, _y, w, th = _data(rng, n, d, jnp.float64)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n))
    g, l = kernels.logreg_grad(x, y, w, th, lam=lam)
    gr, lr = ref.logreg_grad_ref(x, y, w, th, lam)
    np.testing.assert_allclose(g, gr, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(l[0], lr, rtol=1e-10)


@given(scale=st.sampled_from([1e2, 1e4, 1e8]), seed=st.integers(0, 100))
def test_logreg_extreme_margins_stable(scale, seed):
    """No overflow/NaN at |margin| up to 1e8 — the stable-sigmoid path."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(20, 4)) * scale)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=20))
    w = jnp.ones(20, jnp.float64)
    th = jnp.asarray(rng.normal(size=4))
    g, l = kernels.logreg_grad(x, y, w, th, lam=1e-3)
    gr, lr = ref.logreg_grad_ref(x, y, w, th, 1e-3)
    assert np.isfinite(np.asarray(g)).all() and np.isfinite(float(l[0]))
    np.testing.assert_allclose(g, gr, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(l[0], lr, rtol=1e-9)


def test_logreg_grad_is_autodiff_grad():
    rng = np.random.default_rng(7)
    x, _y, w, th = _data(rng, 24, 5, jnp.float64)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=24))
    lam = 1e-3

    def loss_fn(t):
        return jnp.sum(w * jnp.logaddexp(0.0, -y * (x @ t))) + 0.5 * lam * jnp.dot(t, t)

    g_auto = jax.grad(loss_fn)(th)
    g, l = kernels.logreg_grad(x, y, w, th, lam=lam, block_n=8)
    np.testing.assert_allclose(g, g_auto, rtol=1e-9)
    np.testing.assert_allclose(l[0], loss_fn(th), rtol=1e-12)


def test_logreg_regularizer_applied_exactly_once():
    """Multi-block grids must not re-add lam*theta per block."""
    rng = np.random.default_rng(11)
    x, _y, w, th = _data(rng, 48, 6, jnp.float64)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=48))
    for bn in (48, 16, 8, 4, 1):
        g, l = kernels.logreg_grad(x, y, w, th, lam=0.5, block_n=bn)
        gr, lr = ref.logreg_grad_ref(x, y, w, th, 0.5)
        np.testing.assert_allclose(g, gr, rtol=1e-10, err_msg=f"bn={bn}")
        np.testing.assert_allclose(l[0], lr, rtol=1e-10, err_msg=f"bn={bn}")


def test_logreg_zero_lambda_no_reg():
    rng = np.random.default_rng(13)
    x, _y, w, _ = _data(rng, 16, 3, jnp.float64)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=16))
    th = jnp.zeros(3, jnp.float64)
    _, l = kernels.logreg_grad(x, y, w, th, lam=0.0)
    # at theta = 0 the loss is sum(w) * log(2)
    np.testing.assert_allclose(l[0], float(jnp.sum(w)) * np.log(2.0), rtol=1e-12)


# ---------------------------------------------------------------------------
# blocked matmul
# ---------------------------------------------------------------------------

@given(m=st.sampled_from([16, 32, 64, 128]),
       k=st.sampled_from([16, 32, 64]),
       n=st.sampled_from([16, 48, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    np.testing.assert_allclose(kernels.pmatmul(a, b), a @ b, rtol=2e-4, atol=2e-4)


@given(bm=st.sampled_from([8, 16, 32, 64]),
       bk=st.sampled_from([8, 16, 32]),
       bn=st.sampled_from([8, 16, 64]))
def test_matmul_block_schedule_invariant(bm, bk, bn):
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    out = _pallas_matmul(a, b, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(out, a @ b, rtol=2e-4, atol=2e-4)


def test_matmul_vjp_matches_autodiff():
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(16, 48)), jnp.float32)
    f1 = lambda a, b: jnp.sum(jnp.tanh(kernels.pmatmul(a, b)))  # noqa: E731
    f2 = lambda a, b: jnp.sum(jnp.tanh(a @ b))  # noqa: E731
    g1a, g1b = jax.grad(f1, argnums=(0, 1))(a, b)
    g2a, g2b = jax.grad(f2, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(g1a, g2a, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(g1b, g2b, rtol=1e-3, atol=1e-4)


def test_matmul_f64():
    rng = np.random.default_rng(10)
    a = jnp.asarray(rng.normal(size=(32, 32)), jnp.float64)
    b = jnp.asarray(rng.normal(size=(32, 32)), jnp.float64)
    np.testing.assert_allclose(kernels.pmatmul(a, b), a @ b, rtol=1e-12)


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 4096), target=st.integers(1, 256))
def test_pick_block_divides_and_bounded(n, target):
    b = pick_block(n, target)
    assert n % b == 0
    assert 1 <= b <= min(n, target)


@given(n=st.integers(1, 512))
def test_pick_block_maximal(n):
    b = pick_block(n, 64)
    for cand in range(b + 1, min(n, 64) + 1):
        assert n % cand != 0, f"{cand} is a larger valid divisor than {b}"
