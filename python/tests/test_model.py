"""L2 correctness: the per-worker worker computations that get AOT'd."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given
import hypothesis.strategies as st

from compile import model
from compile.shapes import LOGREG_LAMBDA


def _shard(seed, n=50, d=50):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)))
    y = jnp.asarray(rng.normal(size=n))
    w = jnp.ones(n, jnp.float64)
    th = jnp.asarray(rng.normal(size=d))
    return x, y, w, th


@given(seed=st.integers(0, 2**31 - 1))
def test_linreg_worker_matches_ref_path(seed):
    x, y, w, th = _shard(seed)
    g, l = model.linreg_worker(x, y, w, th)
    gr, lr = model.linreg_worker_ref(x, y, w, th)
    np.testing.assert_allclose(g, gr, rtol=1e-10)
    np.testing.assert_allclose(l, lr, rtol=1e-10)


@given(seed=st.integers(0, 2**31 - 1))
def test_logreg_worker_matches_ref_path(seed):
    rng = np.random.default_rng(seed)
    x, _y, w, th = _shard(seed)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=50))
    g, l = model.logreg_worker(x, y, w, th)
    gr, lr = model.logreg_worker_ref(x, y, w, th)
    np.testing.assert_allclose(g, gr, rtol=1e-10)
    np.testing.assert_allclose(l, lr, rtol=1e-10)


def test_linreg_worker_jits_and_is_deterministic():
    x, y, w, th = _shard(0)
    f = jax.jit(model.linreg_worker)
    g1, l1 = f(x, y, w, th)
    g2, l2 = f(x, y, w, th)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert float(l1) == float(l2)


def test_logreg_worker_default_lambda_is_papers():
    """Paper §4: lambda = 1e-3 for all logistic experiments."""
    assert LOGREG_LAMBDA == 1e-3
    x, _y, w, _th = _shard(1)
    y = jnp.asarray(np.random.default_rng(1).choice([-1.0, 1.0], size=50))
    th = jnp.zeros(50, jnp.float64)
    _, l_default = model.logreg_worker(x, y, w, th)
    _, l_explicit = model.logreg_worker(x, y, w, th, lam=1e-3)
    assert float(l_default) == float(l_explicit)


def test_worker_loss_scalar_shape():
    x, y, w, th = _shard(2)
    _, l = model.linreg_worker(x, y, w, th)
    assert jnp.shape(l) == ()


def test_gradient_descent_on_worker_converges():
    """Sanity: plain GD with alpha=1/L drives the worker loss to its min —
    the artifact really is a usable gradient."""
    rng = np.random.default_rng(4)
    n, d = 50, 10
    x = jnp.asarray(rng.normal(size=(n, d)))
    th_star = jnp.asarray(rng.normal(size=d))
    y = x @ th_star
    w = jnp.ones(n, jnp.float64)
    lmax = 2.0 * float(jnp.linalg.eigvalsh(x.T @ x)[-1])
    th = jnp.zeros(d, jnp.float64)
    for _ in range(300):
        g, _ = model.linreg_worker(x, y, w, th)
        th = th - (1.0 / lmax) * g
    _, l = model.linreg_worker(x, y, w, th)
    assert float(l) < 1e-8
