import os
import sys

# Regression artifacts are f64; enable x64 before any test imports jax arrays.
import jax

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
